//! Hash-of-app sharding of one trace across `SweepRunner` workers.
//!
//! A shard owns the apps whose [`app_hash`] lands on it; every row of an
//! app — its whole invocation chain — therefore replays on exactly one
//! shard, so chain prediction always sees complete sequences. Each worker
//! streams the trace itself (CSV: its own reader over the file; synth: it
//! materialises only the apps it owns), replays its apps in sorted-app
//! order, and folds their metrics into one [`MacroMetrics`].
//!
//! **Determinism contract** (the harness's "across grid points" guarantee
//! extended to *within one trace*): per-app replay depends only on
//! `(app rows, run seed)`, and the merge is a commutative sum of `u64`s —
//! so the merged metrics are byte-identical for ANY `--shards` value and
//! ANY `--parallel` value, not merely for a fixed grid. The
//! `azure_macro_determinism` regression test pins `--shards 1/2/8 ×
//! --parallel 1/4`.
//!
//! **Shared-pool mode** keeps the weaker half of that contract: a shard's
//! world depends only on `(shard contents, shard index, run seed)`, so at
//! a FIXED `--shards` the merge is still byte-identical for any
//! `--parallel` — but changing the shard count regroups tenants into
//! different clusters and legitimately changes contention.
//!
//! Cost model: a CSV replay scans the file once per shard (workers scan
//! concurrently); rows not owned by the shard are parsed and dropped, and
//! only the owned rows' compact per-minute counts are held in memory.

use std::path::PathBuf;

use anyhow::{bail, Result};

use crate::experiments::harness::SweepRunner;
use crate::util::fxhash::FxHashMap;
use crate::workload::macrotrace::ingest::{AzureTraceReader, TraceRow};
use crate::workload::macrotrace::replay::{
    app_hash, replay_app, replay_pool_days, shared_world_seed, MacroMetrics, PoolMode,
    ReplayCfg,
};
use crate::workload::macrotrace::synth::{app_rows, app_rows_for_day, SynthTraceCfg};

/// Stable shard assignment for an app.
pub fn shard_of(app: &str, shards: usize) -> usize {
    debug_assert!(shards > 0, "shard_of with zero shards");
    (app_hash(app) % shards.max(1) as u64) as usize
}

/// Where the trace comes from: a CSV on disk, or the offline synthesizer.
#[derive(Debug, Clone)]
pub enum TraceSource {
    Csv(PathBuf),
    Synth(SynthTraceCfg),
}

/// One shard's replay output.
#[derive(Debug, Clone, Default)]
pub struct ShardOut {
    pub metrics: MacroMetrics,
    /// Trace rows this shard parsed and owned.
    pub rows: u64,
    /// Malformed rows its reader skipped (CSV only; whole-file count).
    pub skipped: u64,
}

/// One shard's materialised slice of the trace: its apps (sorted by name,
/// rows in trace order) plus the scan's skip count. This is the unit the
/// experiment grid reuses — gather once, replay under every
/// `(variant, seed)` combination.
pub type ShardApps = crate::workload::macrotrace::replay::AppRows;

/// Gather the rows owned by `shard` (of `shards`): one streaming pass for
/// CSV sources (an I/O error mid-scan is a hard error, never a silent
/// truncation), direct materialisation of owned apps for synth sources.
/// Returns `(apps, skipped_rows)`.
pub fn load_shard_apps(
    src: &TraceSource,
    shard: usize,
    shards: usize,
) -> Result<(ShardApps, u64)> {
    match src {
        TraceSource::Csv(path) => {
            let mut reader = AzureTraceReader::open(path)?;
            let mut by_app: FxHashMap<String, Vec<TraceRow>> = FxHashMap::default();
            for row in &mut reader {
                if shard_of(&row.app, shards) == shard {
                    by_app.entry(row.app.clone()).or_default().push(row);
                }
            }
            if let Some(e) = reader.io_error() {
                bail!("reading trace {}: {e}", path.display());
            }
            // Sorted-app order: deterministic regardless of hash-map
            // iteration order (rows within an app keep file order).
            let mut apps: ShardApps = by_app.into_iter().collect();
            apps.sort_by(|a, b| a.0.cmp(&b.0));
            Ok((apps, reader.skipped() as u64))
        }
        TraceSource::Synth(synth) => {
            let mut apps: ShardApps = Vec::new();
            for i in 0..synth.apps {
                let app = format!("app-{i}");
                if shard_of(&app, shards) != shard {
                    continue;
                }
                apps.push((app, app_rows(synth, i)));
            }
            // Already sorted-by-construction? No: "app-10" < "app-2"
            // lexicographically — sort to match the CSV path exactly.
            apps.sort_by(|a, b| a.0.cmp(&b.0));
            Ok((apps, 0))
        }
    }
}

/// The synth app indices owned by `shard`, paired with their names and
/// sorted by name (matching [`load_shard_apps`]' ordering exactly). The
/// index is what multi-day replays need to materialise later day slices.
pub fn shard_synth_apps(
    synth: &SynthTraceCfg,
    shard: usize,
    shards: usize,
) -> Vec<(String, usize)> {
    let mut apps: Vec<(String, usize)> = (0..synth.apps)
        .map(|i| (format!("app-{i}"), i))
        .filter(|(app, _)| shard_of(app, shards) == shard)
        .collect();
    apps.sort_by(|a, b| a.0.cmp(&b.0));
    apps
}

/// Materialise day `day`'s rows for a shard's synth apps, in the same
/// (name-sorted) order as the day-0 slice.
pub fn shard_synth_day(
    synth: &SynthTraceCfg,
    apps: &[(String, usize)],
    day: usize,
) -> ShardApps {
    apps.iter()
        .map(|(app, i)| (app.clone(), app_rows_for_day(synth, *i, day)))
        .collect()
}

/// Replay one shard's apps under `cfg`'s pool mode: isolated per-app
/// worlds, or one shared memory-bounded world for the whole slice.
pub fn replay_shard_apps(
    apps: &[(String, Vec<TraceRow>)],
    shard: usize,
    cfg: &ReplayCfg,
) -> MacroMetrics {
    match cfg.pool {
        PoolMode::PerApp => {
            let mut out = MacroMetrics::default();
            for (app, rows) in apps {
                out.merge(&replay_app(app, rows, cfg));
            }
            out
        }
        PoolMode::Shared => {
            if apps.is_empty() {
                return MacroMetrics::default();
            }
            let days = [apps.to_vec()];
            replay_pool_days(&days, cfg, shared_world_seed(cfg.seed, shard), 0)
                .pop()
                .expect("single-day replay yields one slice")
        }
    }
}

/// Replay the slice of `src` owned by `shard` (of `shards`).
pub fn replay_shard(
    src: &TraceSource,
    shard: usize,
    shards: usize,
    cfg: &ReplayCfg,
) -> Result<ShardOut> {
    let (apps, skipped) = load_shard_apps(src, shard, shards)?;
    let mut out = ShardOut {
        skipped,
        ..ShardOut::default()
    };
    out.rows = apps.iter().map(|(_, r)| r.len() as u64).sum();
    out.metrics = replay_shard_apps(&apps, shard, cfg);
    Ok(out)
}

/// Replay the whole trace: fan the shards over `runner`'s workers and
/// merge in shard order (the sums are order-independent anyway; the fixed
/// order keeps `rows`/`skipped` reporting stable too).
pub fn replay_sharded(
    src: &TraceSource,
    shards: usize,
    cfg: &ReplayCfg,
    runner: &SweepRunner,
) -> Result<ShardOut> {
    let shards = shards.max(1);
    let grid: Vec<usize> = (0..shards).collect();
    let results = runner.run(&grid, |_, &shard| replay_shard(src, shard, shards, cfg));
    let mut merged = ShardOut::default();
    for (shard, r) in results.into_iter().enumerate() {
        let out = r?;
        merged.metrics.merge(&out.metrics);
        merged.rows += out.rows;
        // Every CSV shard scans (and skip-counts) the whole file; report
        // the per-scan number once, not `shards` times.
        if shard == 0 {
            merged.skipped = out.skipped;
        }
    }
    Ok(merged)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synth_src() -> TraceSource {
        TraceSource::Synth(SynthTraceCfg {
            apps: 30,
            minutes: 12,
            seed: 5,
            ..SynthTraceCfg::default()
        })
    }

    fn cfg() -> ReplayCfg {
        let mut c = ReplayCfg::default();
        c.warmup_minutes = 3;
        c
    }

    #[test]
    fn every_app_lands_on_exactly_one_shard() {
        for shards in [1usize, 2, 3, 8] {
            for i in 0..50 {
                let app = format!("app-{i}");
                let s = shard_of(&app, shards);
                assert!(s < shards);
                // Stable across calls.
                assert_eq!(s, shard_of(&app, shards));
            }
        }
    }

    #[test]
    fn shard_slices_partition_the_trace_exactly() {
        let src = synth_src();
        let shards = 3;
        let mut seen = std::collections::HashSet::new();
        let mut total_rows = 0u64;
        for s in 0..shards {
            let (apps, skipped) = load_shard_apps(&src, s, shards).unwrap();
            assert_eq!(skipped, 0);
            // Sorted-app order within the slice.
            assert!(apps.windows(2).all(|w| w[0].0 < w[1].0));
            for (app, rows) in &apps {
                assert!(seen.insert(app.clone()), "app {app} landed on two shards");
                total_rows += rows.len() as u64;
            }
        }
        let (all, _) = load_shard_apps(&src, 0, 1).unwrap();
        assert_eq!(seen.len(), all.len(), "every app on exactly one shard");
        assert_eq!(
            total_rows,
            all.iter().map(|(_, r)| r.len() as u64).sum::<u64>()
        );
    }

    #[test]
    fn sharded_merge_matches_serial_replay() {
        let src = synth_src();
        let cfg = cfg();
        let serial = replay_sharded(&src, 1, &cfg, &SweepRunner::new(1)).unwrap();
        assert!(serial.metrics.invocations > 0, "synth trace drove work");
        for (shards, parallel) in [(2usize, 1usize), (3, 4), (8, 4)] {
            let sharded =
                replay_sharded(&src, shards, &cfg, &SweepRunner::new(parallel)).unwrap();
            assert_eq!(
                serial.metrics.digest(),
                sharded.metrics.digest(),
                "shards={shards} parallel={parallel} diverged"
            );
            assert_eq!(serial.metrics, sharded.metrics);
            assert_eq!(serial.rows, sharded.rows);
        }
    }

    #[test]
    fn csv_and_synth_sources_replay_identically() {
        let TraceSource::Synth(synth) = synth_src() else {
            unreachable!()
        };
        let mut buf = Vec::new();
        crate::workload::macrotrace::synth::write_csv(&synth, &mut buf).unwrap();
        let dir = std::env::temp_dir().join("freshen-macrotrace-shard-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.csv");
        std::fs::write(&path, &buf).unwrap();
        let cfg = cfg();
        let from_synth =
            replay_sharded(&TraceSource::Synth(synth), 2, &cfg, &SweepRunner::new(2)).unwrap();
        let from_csv =
            replay_sharded(&TraceSource::Csv(path), 2, &cfg, &SweepRunner::new(2)).unwrap();
        assert_eq!(from_synth.metrics.digest(), from_csv.metrics.digest());
        assert_eq!(from_synth.rows, from_csv.rows);
        assert_eq!(from_csv.skipped, 0);
    }

    #[test]
    fn missing_csv_errors() {
        let src = TraceSource::Csv(PathBuf::from("/nonexistent/azure.csv"));
        assert!(replay_shard(&src, 0, 1, &cfg()).is_err());
    }

    #[test]
    fn synth_index_slices_match_the_row_loader() {
        let TraceSource::Synth(synth) = synth_src() else {
            unreachable!()
        };
        for shard in 0..3 {
            let idx = shard_synth_apps(&synth, shard, 3);
            let (apps, _) = load_shard_apps(&synth_src(), shard, 3).unwrap();
            assert_eq!(idx.len(), apps.len());
            for ((name_i, i), (name_a, rows)) in idx.iter().zip(apps.iter()) {
                assert_eq!(name_i, name_a, "index slice order matches loader order");
                let day0 = shard_synth_day(&synth, &[(name_i.clone(), *i)], 0);
                assert_eq!(&day0[0].1, rows);
            }
        }
    }

    #[test]
    fn shared_pool_is_parallelism_invariant_at_fixed_shards() {
        let src = synth_src();
        let mut cfg = cfg();
        cfg.pool = crate::workload::macrotrace::replay::PoolMode::Shared;
        let shards = 3;
        let serial = replay_sharded(&src, shards, &cfg, &SweepRunner::new(1)).unwrap();
        assert!(serial.metrics.invocations > 0);
        let parallel = replay_sharded(&src, shards, &cfg, &SweepRunner::new(4)).unwrap();
        assert_eq!(
            serial.metrics.digest(),
            parallel.metrics.digest(),
            "fixed shards must be parallelism-invariant in shared mode"
        );
        // Shared pools genuinely contend: the same trace through one
        // 16 GB-equivalent cluster differs from isolated microcosms.
        let mut per_app = cfg.clone();
        per_app.pool = crate::workload::macrotrace::replay::PoolMode::PerApp;
        let isolated = replay_sharded(&src, shards, &per_app, &SweepRunner::new(2)).unwrap();
        assert_eq!(
            isolated.metrics.invocations, serial.metrics.invocations,
            "both modes replay the same arrival volume"
        );
    }
}
