//! Azure-trace macro benchmark: streaming ingestion, offline synthesis,
//! per-app platform replay, and deterministic hash-of-app sharding.
//!
//! This is the subsystem behind `repro azure-macro` — the repo's first
//! literature-comparable, platform-scale benchmark (SPES and the vHive
//! snapshot study both evaluate against the Azure Functions 2019 trace).
//! Four modules, composing bottom-up:
//!
//! - [`ingest`] — a streaming, chunked reader for the Azure Functions 2019
//!   CSV schema (per-function per-minute invocation counts plus optional
//!   duration/memory columns). One row in memory at a time; the full trace
//!   is never materialised.
//! - [`synth`] — a deterministic synthesizer calibrated to the published
//!   distributions (via [`crate::workload::azure`]), so the benchmark runs
//!   offline with no trace download. App `i`'s rows depend only on
//!   `(seed, i)`, which is what lets shards materialise exactly the apps
//!   they own.
//! - [`replay`] — drives apps through the full [`platform::World`]
//!   (freshen gate, chain + histogram predictors with their bulk-warmup
//!   paths, memory-accounted container pool, netsim), producing
//!   integer-only, mergeable [`replay::MacroMetrics`]. Two pool modes:
//!   isolated per-app worlds (default) or one shared memory-bounded
//!   world per shard ([`replay::PoolMode::Shared`]) where tenants
//!   genuinely contend for warm containers; plus multi-day replay with
//!   state carried across day boundaries ([`replay::replay_pool_days`]).
//! - [`shard`] — partitions a trace across [`SweepRunner`] workers by
//!   hash-of-app (whole chains stay on one shard) with a merge that is
//!   byte-identical for any `--shards` × `--parallel` combination in
//!   per-app mode, and for any `--parallel` at fixed `--shards` in
//!   shared mode.
//!
//! The experiment harness on top lives in
//! [`crate::experiments::azure_macro`]; the CLI entry points are
//! `repro azure-macro` and `repro gen-azure-trace`.
//!
//! [`platform::World`]: crate::platform::world::World
//! [`SweepRunner`]: crate::experiments::harness::SweepRunner

pub mod ingest;
pub mod replay;
pub mod shard;
pub mod synth;

pub use ingest::{AzureTraceReader, TraceRow};
pub use replay::{
    replay_app, replay_pool_days, MacroMetrics, PoolMode, PredictorPolicy, ReplayCfg,
};
pub use shard::{
    load_shard_apps, replay_shard, replay_sharded, shard_of, ShardApps, ShardOut, TraceSource,
};
pub use synth::{app_rows, app_rows_for_day, write_csv, SynthSummary, SynthTraceCfg};
