//! Trace replay onto the full platform (`platform::World`), in two pool
//! modes.
//!
//! **Per-app mode (default): unit of replay = one application.** Each app
//! runs in its own `World` whose RNG stream is derived from `(run seed,
//! hash(app))`, with all of its functions deployed together (so chain
//! prediction and per-app isolation see the complete invocation sequence
//! — the reason sharding partitions by hash-of-app, never by row). Azure
//! apps are isolated tenants: containers are never shared across apps on
//! the real platform either, so per-app worlds change no semantics — and
//! they are what makes the merged metrics *provably* independent of the
//! shard map. An app's replay depends only on its own rows and the run
//! seed; the merge ([`MacroMetrics::merge`]) is a commutative sum of
//! `u64` counters and histogram bins. Shards 1/2/8, parallel 1/4 — same
//! bytes out.
//!
//! **Shared-pool mode** ([`PoolMode::Shared`]): all of a shard's apps are
//! deployed into ONE memory-bounded `World`, so warm containers genuinely
//! compete — the cross-app contention that makes keep-alive policy
//! matter. The price is a weaker determinism contract: an app's replay
//! now depends on its shard-mates, so merged metrics are byte-identical
//! only at a **fixed `--shards`** (still for ANY `--parallel`, because
//! shard contents and the per-shard world seed depend only on the shard
//! index).
//!
//! Replay of one world (one app, or a shard's worth):
//! 1. deploy every row as a paper-λ (`DataGet → Compute(duration) →
//!    DataPut`), wiring `orchestration` rows into an explicit chain
//!    (`InvokeNext` via the Step Functions trigger) when the predictor
//!    policy enables chains;
//! 2. bulk-warm the histogram/chain predictors from the first
//!    `warmup_minutes` of counts (no simulator events — the predictors'
//!    dedicated warmup path);
//! 3. expand the remaining per-minute counts lazily into `invoke` events
//!    (counts are the compact form; the event stream never materialises
//!    outside the wheel) and run the world to quiescence.
//!
//! **Multi-day horizons:** [`replay_pool_days`] takes one row set per
//! day (same apps, same functions; only the counts differ — see
//! [`crate::workload::macrotrace::synth::app_rows_for_day`]), schedules
//! every day's arrivals up front at `day × day_minutes` offsets, and
//! drops a snapshot event at each day boundary. The world — container
//! pool, predictor state, freshen caches — carries across days; metrics
//! come back per-day (cumulative = merge of the days).

use std::cell::RefCell;
use std::hash::Hasher;
use std::rc::Rc;

use crate::metrics::hist::LatencyHist;
use crate::netsim::link::Site;
use crate::obs::{SpanSink, Tracer, WindowSet};
use crate::platform::endpoint::Endpoint;
use crate::platform::exec::PlatformEvent;
use crate::platform::function::{Arg, FunctionSpec, Op};
use crate::platform::symbols::FnId;
use crate::platform::world::{PlatformSim, World};
use crate::simcore::Sim;
use crate::triggers::TriggerService;
use crate::util::config::{Config, KeepAliveKind};
use crate::util::fxhash::FxHasher;
use crate::util::rng::{mix64, Rng};
use crate::util::time::{SimDuration, SimTime};
use crate::workload::macrotrace::ingest::TraceRow;

/// One trace minute, in simulator microseconds.
pub const MINUTE: SimDuration = SimDuration(60_000_000);

/// One world's worth of apps: `(name, rows)` pairs in name-sorted order
/// (the same shape `shard::ShardApps` aliases).
pub type AppRows = Vec<(String, Vec<TraceRow>)>;

/// Which prediction sources feed freshen during replay (the experiment's
/// ablation axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PredictorPolicy {
    /// No prediction at all (the freshen-off baseline).
    None,
    /// IAT-histogram predictions only; chains replay as independent rows.
    Histogram,
    /// Explicit-chain predictions only.
    Chain,
    /// Both sources (the paper's full system).
    Both,
}

impl PredictorPolicy {
    // User-facing string parsing lives on `experiments::azure_macro::
    // Variant` (the CLI surface); this enum stays a plain internal switch.
    fn histogram(&self) -> bool {
        matches!(self, PredictorPolicy::Histogram | PredictorPolicy::Both)
    }

    fn chain(&self) -> bool {
        matches!(self, PredictorPolicy::Chain | PredictorPolicy::Both)
    }
}

/// How a replay worlds its apps: isolated per-app microcosms, or one
/// shared memory-bounded cluster per shard.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PoolMode {
    /// One `World` per application (the historical mode; byte-identical
    /// merges for ANY shards × parallel).
    #[default]
    PerApp,
    /// One `World` per shard: every app in the shard shares one
    /// memory-bounded container pool (byte-identical merges at fixed
    /// shards, for any parallel).
    Shared,
}

impl PoolMode {
    pub fn parse(s: &str) -> Option<PoolMode> {
        match s {
            "per-app" | "per_app" | "perapp" => Some(PoolMode::PerApp),
            "shared" => Some(PoolMode::Shared),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            PoolMode::PerApp => "per-app",
            PoolMode::Shared => "shared",
        }
    }
}

/// Replay configuration shared by every app of a run.
#[derive(Debug, Clone)]
pub struct ReplayCfg {
    /// Platform config template (freshen switch, pool sizing, keep-alive
    /// policy); the seed field is overwritten per world.
    pub base: Config,
    /// Run seed; worlds derive their streams from `(seed, app)` (per-app
    /// mode) or `(seed, shard)` (shared mode).
    pub seed: u64,
    /// Leading minutes fed to the predictors instead of simulated.
    pub warmup_minutes: usize,
    pub policy: PredictorPolicy,
    /// Per-app worlds or one shared pool per shard.
    pub pool: PoolMode,
    /// Record lifecycle spans into each world's `obs::Tracer` and merge
    /// them into `MacroMetrics::spans`. Off by default: the disabled hot
    /// path is a single bool test and every legacy digest is unchanged.
    pub trace_spans: bool,
    /// Per-world span ring capacity (oldest events drop past it).
    pub span_cap: usize,
    /// Keep only spans whose function name contains this substring
    /// (shared pools qualify names `app/function`, so an app name
    /// selects a whole tenant).
    pub span_filter: Option<String>,
    /// Accumulate rolling per-function telemetry windows into
    /// `MacroMetrics::fn_windows`. Off by default.
    pub fn_windows: bool,
}

impl Default for ReplayCfg {
    fn default() -> ReplayCfg {
        let mut base = Config::default();
        // Match the e2e experiment's admission threshold so macro results
        // compare against the repo's headline numbers.
        base.freshen.min_confidence = 0.3;
        ReplayCfg {
            base,
            seed: 2020,
            warmup_minutes: 10,
            policy: PredictorPolicy::Both,
            pool: PoolMode::PerApp,
            trace_spans: false,
            span_cap: crate::obs::DEFAULT_SPAN_CAP,
            span_filter: None,
            fn_windows: false,
        }
    }
}

/// Merged replay metrics. Integer-only by design: merging is a
/// commutative, associative sum (`peak_resident_mb` merges by `max`,
/// also commutative/associative), so the result is byte-identical for
/// any partition of the same worlds across shards/workers. (Latency
/// percentiles and rates are *derived* from these integers at report
/// time.)
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MacroMetrics {
    pub apps: u64,
    pub functions: u64,
    pub invocations: u64,
    pub cold_starts: u64,
    pub warm_starts: u64,
    /// Dispatches served by restoring a snapshotted container (the third
    /// start kind; zero unless the snapshot mitigation is enabled).
    pub restored_starts: u64,
    /// Warm containers demoted to the snapshotted state instead of
    /// evicted.
    pub snapshots: u64,
    /// Total restore latency paid (base + page-in), µs.
    pub restore_us: u64,
    /// Hybrid freshen runs launched from the restore path.
    pub freshens_on_restore: u64,
    pub freshens_started: u64,
    pub freshens_completed: u64,
    pub freshens_wasted: u64,
    /// Freshen resource hits / total resource touches across invocations.
    pub freshen_hits: u64,
    pub freshen_total: u64,
    /// Network bytes billed / saved (rounded to integer bytes so merges
    /// stay order-independent — f64 addition is not associative).
    pub network_bytes: u64,
    pub network_bytes_saved: u64,
    /// Simulator events executed (replay throughput accounting).
    pub sim_events: u64,
    /// Apps replayed with an active explicit chain.
    pub chains: u64,
    /// Apps whose `orchestration` rows did NOT mirror the head's counts
    /// and were therefore replayed as independent rows (real-CSV safety:
    /// keeps every variant's invocation volume comparable).
    pub chains_demoted: u64,
    /// Container evictions, total and by cause (idle-TTL/keep-alive
    /// expiry vs memory-pressure reclaim).
    pub evictions: u64,
    pub evictions_idle: u64,
    pub evictions_pressure: u64,
    /// Pressure evictions that destroyed live warm state.
    pub warm_kills: u64,
    /// Distinct invocations that waited in the dispatch queue.
    pub queued_total: u64,
    /// Deepest any constituent world's dispatch queue got (merged by
    /// `max`, like the resident-memory peak).
    pub queue_peak_depth: u64,
    /// Total time invocations spent queued for cluster memory, µs.
    pub queue_wait_us: u64,
    /// Longest single queue wait over any constituent world, µs
    /// (merged by `max`).
    pub queue_wait_max_us: u64,
    /// Freshen runs aborted by the container-incarnation guard.
    pub stale_freshen_aborts: u64,
    /// Invocations dropped because no host could ever admit their charge
    /// (conservation: arrivals == completions + drops).
    pub dropped_infeasible: u64,
    /// Peak resident container memory over any constituent world, MB
    /// (merged by `max`: the largest single-world peak).
    pub peak_resident_mb: u64,
    /// Integral of resident container memory, MB·µs (divide by 1e6 for
    /// MB·s), summed across worlds.
    pub resident_mb_us: u64,
    pub latency: LatencyHist,
    /// Merged lifecycle span streams (empty unless `ReplayCfg::
    /// trace_spans`). Deliberately excluded from every digest string so
    /// the pinned metric digests are independent of tracing.
    pub spans: SpanSink,
    /// Merged per-function telemetry windows (empty unless `ReplayCfg::
    /// fn_windows`); excluded from the digest strings like `spans`.
    pub fn_windows: WindowSet,
}

impl MacroMetrics {
    /// Commutative merge (see type-level docs).
    pub fn merge(&mut self, other: &MacroMetrics) {
        self.apps += other.apps;
        self.functions += other.functions;
        self.invocations += other.invocations;
        self.cold_starts += other.cold_starts;
        self.warm_starts += other.warm_starts;
        self.restored_starts += other.restored_starts;
        self.snapshots += other.snapshots;
        self.restore_us = self.restore_us.saturating_add(other.restore_us);
        self.freshens_on_restore += other.freshens_on_restore;
        self.freshens_started += other.freshens_started;
        self.freshens_completed += other.freshens_completed;
        self.freshens_wasted += other.freshens_wasted;
        self.freshen_hits += other.freshen_hits;
        self.freshen_total += other.freshen_total;
        self.network_bytes += other.network_bytes;
        self.network_bytes_saved += other.network_bytes_saved;
        self.sim_events += other.sim_events;
        self.chains += other.chains;
        self.chains_demoted += other.chains_demoted;
        self.evictions += other.evictions;
        self.evictions_idle += other.evictions_idle;
        self.evictions_pressure += other.evictions_pressure;
        self.warm_kills += other.warm_kills;
        self.queued_total += other.queued_total;
        self.queue_peak_depth = self.queue_peak_depth.max(other.queue_peak_depth);
        self.queue_wait_us = self.queue_wait_us.saturating_add(other.queue_wait_us);
        self.queue_wait_max_us = self.queue_wait_max_us.max(other.queue_wait_max_us);
        self.stale_freshen_aborts += other.stale_freshen_aborts;
        self.dropped_infeasible += other.dropped_infeasible;
        self.peak_resident_mb = self.peak_resident_mb.max(other.peak_resident_mb);
        self.resident_mb_us = self.resident_mb_us.saturating_add(other.resident_mb_us);
        self.latency.merge(&other.latency);
        self.spans.merge(&other.spans);
        self.fn_windows.merge(&other.fn_windows);
    }

    /// Fingerprint of the merged span stream — the string the trace
    /// shard-determinism tests compare byte-for-byte. Kept separate from
    /// [`MacroMetrics::digest`] so the pinned metric digests never move
    /// when tracing is toggled.
    pub fn span_digest(&self) -> String {
        format!(
            "{:016x} n={} drop={}",
            self.spans.digest(),
            self.spans.len(),
            self.spans.dropped,
        )
    }

    pub fn cold_start_rate(&self) -> f64 {
        if self.invocations == 0 {
            0.0
        } else {
            self.cold_starts as f64 / self.invocations as f64
        }
    }

    pub fn freshen_hit_rate(&self) -> f64 {
        if self.freshen_total == 0 {
            0.0
        } else {
            self.freshen_hits as f64 / self.freshen_total as f64
        }
    }

    /// Fraction of admitted freshens whose predicted invocation never
    /// arrived (the paper's wasted-work/billing concern).
    pub fn wasted_freshen_fraction(&self) -> f64 {
        if self.freshens_started == 0 {
            0.0
        } else {
            self.freshens_wasted as f64 / self.freshens_started as f64
        }
    }

    /// Fraction of evictions that killed live warm state under pressure.
    pub fn warm_kill_rate(&self) -> f64 {
        if self.evictions == 0 {
            0.0
        } else {
            self.warm_kills as f64 / self.evictions as f64
        }
    }

    /// Resident-memory integral in MB·s (derived; the stored counter is
    /// integer MB·µs).
    pub fn resident_mb_s(&self) -> f64 {
        self.resident_mb_us as f64 / 1e6
    }

    /// Total queue wait in seconds (derived; stored as integer µs).
    pub fn queue_wait_s(&self) -> f64 {
        self.queue_wait_us as f64 / 1e6
    }

    /// Longest single queue wait in ms.
    pub fn queue_wait_max_ms(&self) -> f64 {
        self.queue_wait_max_us as f64 / 1e3
    }

    pub fn p50_ms(&self) -> f64 {
        self.latency.quantile_ms(50.0)
    }

    pub fn p99_ms(&self) -> f64 {
        self.latency.quantile_ms(99.0)
    }

    /// Canonical content fingerprint — the string the shard-determinism
    /// regression tests compare byte-for-byte. The snapshot-mitigation
    /// counters append as a suffix ONLY when any is nonzero: with the
    /// snapshot axis off they are provably zero (no container can enter
    /// the snapshotted state), so every pinned legacy digest is unchanged
    /// byte-for-byte.
    pub fn digest(&self) -> String {
        let mut d = format!(
            "{} q={}/{} qw={}/{} sa={} dr={}",
            self.digest_pr4(),
            self.queued_total,
            self.queue_peak_depth,
            self.queue_wait_us,
            self.queue_wait_max_us,
            self.stale_freshen_aborts,
            self.dropped_infeasible,
        );
        if self.snapshots != 0 || self.restored_starts != 0 || self.restore_us != 0 {
            d.push_str(&format!(
                " sn={} rs={} rus={} fr={}",
                self.snapshots, self.restored_starts, self.restore_us, self.freshens_on_restore,
            ));
        }
        d
    }

    /// Fraction of completions served by a snapshot restore.
    pub fn restored_start_rate(&self) -> f64 {
        if self.invocations == 0 {
            0.0
        } else {
            self.restored_starts as f64 / self.invocations as f64
        }
    }

    /// Mean restore latency in ms over restored starts.
    pub fn mean_restore_ms(&self) -> f64 {
        if self.restored_starts == 0 {
            0.0
        } else {
            self.restore_us as f64 / self.restored_starts as f64 / 1e3
        }
    }

    /// The pre-dispatch-subsystem digest fields, in their historical
    /// format: what the `LegacyOneShot`-equals-PR-4 golden test pins (the
    /// queue/stale-abort counters did not exist before the extraction, so
    /// they are excluded here; under legacy defaults they are provably
    /// zero-or-derived and the underlying counters are untouched).
    pub fn digest_pr4(&self) -> String {
        format!(
            "{} evict={}/{}/{} wk={} peak={} res={}",
            self.digest_legacy(),
            self.evictions,
            self.evictions_idle,
            self.evictions_pressure,
            self.warm_kills,
            self.peak_resident_mb,
            self.resident_mb_us,
        )
    }

    /// The pre-memory-accounting digest fields, in their historical
    /// format: what the `FixedTtl`-equals-legacy golden test pins (the
    /// new contention counters did not exist before the refactor, so
    /// they are excluded here).
    pub fn digest_legacy(&self) -> String {
        format!(
            "apps={} fns={} inv={} cold={} warm={} fs={} fc={} fw={} fh={}/{} \
             net={} saved={} ev={} ch={}/{} lat={:016x}",
            self.apps,
            self.functions,
            self.invocations,
            self.cold_starts,
            self.warm_starts,
            self.freshens_started,
            self.freshens_completed,
            self.freshens_wasted,
            self.freshen_hits,
            self.freshen_total,
            self.network_bytes,
            self.network_bytes_saved,
            self.sim_events,
            self.chains,
            self.chains_demoted,
            self.latency.digest(),
        )
    }
}

/// Stable 64-bit app identity (FxHash of the app name) — seeds the
/// per-app world and drives shard assignment.
pub fn app_hash(app: &str) -> u64 {
    let mut h = FxHasher::default();
    h.write(app.as_bytes());
    h.finish()
}

/// World seed for a shared-pool shard: depends only on `(run seed,
/// shard index)`, so fixed-shard replays are parallelism-invariant.
pub fn shared_world_seed(seed: u64, shard: usize) -> u64 {
    mix64(seed, mix64(0x5EA6_ED90_0175, shard as u64))
}

/// The 1 MB model-like object every replayed λ fetches (the paper's λ1
/// shape: constant-argument read of a hot object).
const FETCH_BYTES: f64 = 1e6;
const PUT_BYTES: f64 = 64.0 * 1024.0;

/// Jitter-stream tag (see [`Rng`] derivation in [`replay_pool_days`]).
const JITTER_STREAM: u64 = 0xA11C_E500;

/// One deployed app inside a replay world.
struct AppDeployment {
    /// Row indices forming the explicit chain (trigger == orchestration).
    chain: Vec<usize>,
    /// Chain replay active (policy wants chains AND counts mirror).
    chained: bool,
    demoted: bool,
    functions: u64,
    /// Day-0 warmup minutes actually consumed for this app.
    warm: usize,
    /// Deployed function id per row. Per-app worlds use the raw trace
    /// names; a shared world app-qualifies them (`app/function`), because
    /// the Azure dataset's `HashFunction` is a hash of the bare function
    /// NAME and collides across apps — aliasing two tenants onto one
    /// function would silently share their warm containers.
    names: Vec<Rc<str>>,
    /// Interned id per row (same order as `names`): arrivals schedule as
    /// inline [`PlatformEvent::Invoke`] variants — no per-arrival boxed
    /// closure, no name hash at fire time.
    fn_ids: Vec<FnId>,
}

/// Deploy one app's rows into `w` (chain detection + function specs +
/// predictor warmup), mirroring the historical per-app sequence exactly.
fn deploy_and_warm(w: &mut World, app: &str, rows: &[TraceRow], cfg: &ReplayCfg) -> AppDeployment {
    // See `AppDeployment::names`: only the shared pool needs the
    // qualification (and per-app replay must stay byte-identical).
    let names: Vec<Rc<str>> = rows
        .iter()
        .map(|r| match cfg.pool {
            PoolMode::PerApp => Rc::from(r.function.as_str()),
            PoolMode::Shared => Rc::from(format!("{app}/{}", r.function).as_str()),
        })
        .collect();
    // Explicit chain: the app's `orchestration` rows, in row order.
    let chain: Vec<usize> = rows
        .iter()
        .enumerate()
        .filter(|(_, r)| r.trigger == "orchestration")
        .map(|(i, _)| i)
        .collect();
    // Chain replay drives only the head row and lets triggers produce the
    // successors, so it is only workload-preserving when every chain row
    // mirrors the head's counts (the synthesizer guarantees this; a real
    // CSV may not). A non-mirrored chain is DEMOTED to independent-row
    // replay — counted in `chains_demoted` — so every variant of the
    // benchmark replays the same invocation volume and the cross-variant
    // comparison stays honest.
    let mirrored = chain.len() > 1
        && chain
            .iter()
            .all(|&i| rows[i].counts == rows[chain[0]].counts);
    let chained = cfg.policy.chain() && mirrored;

    for (i, row) in rows.iter().enumerate() {
        let mut ops = vec![
            Op::DataGet {
                endpoint: "store".into(),
                creds: Arg::Const("CREDS".into()),
                object_id: Arg::Const("ID1".into()),
            },
            Op::Compute {
                duration: SimDuration::from_millis_f64(row.duration_ms),
            },
            Op::DataPut {
                endpoint: "store".into(),
                creds: Arg::Const("CREDS".into()),
                // App-scoped output id: in a shared world two apps must
                // not collide on the same store key.
                object_id: Arg::Const(format!("out-{app}-{i}")),
                bytes: PUT_BYTES,
            },
        ];
        if chained {
            if let Some(pos) = chain.iter().position(|&c| c == i) {
                if pos + 1 < chain.len() {
                    ops.push(Op::InvokeNext {
                        function: names[chain[pos + 1]].to_string(),
                        trigger: TriggerService::StepFunctions,
                    });
                }
            }
        }
        let mut spec = FunctionSpec::new(&names[i], app, ops);
        spec.memory_mb = row.memory_mb.max(64);
        w.deploy(spec);
    }
    if chained {
        let fns: Vec<String> = chain.iter().map(|&i| names[i].to_string()).collect();
        w.registry
            .register_chain(app, fns)
            .expect("chain functions were just deployed");
    }

    // Bulk predictor warmup from the leading minutes (no sim events).
    let horizon = rows.iter().map(|r| r.counts.len()).max().unwrap_or(0);
    let warm = cfg.warmup_minutes.min(horizon);
    if warm > 0 {
        // Only warm the predictor something will actually consult: the
        // freshen admission path under a histogram policy, or the
        // HybridHistogram keep-alive windows.
        let hist_consulted = cfg.policy.histogram()
            || cfg.base.keep_alive == KeepAliveKind::HybridHistogram;
        if hist_consulted {
            for (i, row) in rows.iter().enumerate() {
                let w_counts = &row.counts[..warm.min(row.counts.len())];
                w.hist_pred.warm_from_minute_counts(
                    &names[i],
                    w_counts,
                    SimTime::ZERO,
                    MINUTE,
                );
            }
        }
        if chained {
            let head_warm: u64 = rows[chain[0]].counts[..warm.min(rows[chain[0]].counts.len())]
                .iter()
                .map(|&c| c as u64)
                .sum();
            if head_warm > 0 {
                for pair in chain.windows(2) {
                    w.chain_pred.warm_edge(
                        &names[pair[0]],
                        &names[pair[1]],
                        head_warm,
                        head_warm,
                    );
                }
            }
        }
    }
    // Deploy interned every name; resolve the ids once so the arrival
    // loop never hashes a name again.
    let fn_ids: Vec<FnId> = names
        .iter()
        .map(|n| w.registry.symbols.lookup(n).expect("just deployed"))
        .collect();
    AppDeployment {
        demoted: cfg.policy.chain() && chain.len() > 1 && !mirrored,
        chained,
        chain,
        functions: rows.len() as u64,
        warm,
        names,
        fn_ids,
    }
}

/// Schedule one app's arrivals for one day. Rows the trace drives
/// directly: everything, except that when the chain is active only its
/// head receives external arrivals (successor counts mirror the head's
/// and are produced by the chain itself).
fn schedule_app_day(
    sim: &mut PlatformSim,
    dep: &AppDeployment,
    rows: &[TraceRow],
    skip_minutes: usize,
    day_base_us: u64,
    jitter: &mut Rng,
) {
    for (i, row) in rows.iter().enumerate() {
        let driven = if dep.chained && row.trigger == "orchestration" {
            i == dep.chain[0]
        } else {
            true
        };
        if !driven {
            continue;
        }
        let fid = dep.fn_ids[i];
        for (m, &c) in row.counts.iter().enumerate().skip(skip_minutes) {
            if c == 0 {
                continue;
            }
            let base_us = day_base_us + m as u64 * MINUTE.micros();
            for j in 0..c as u64 {
                let off = ((j as f64 + jitter.f64()) / c as f64
                    * MINUTE.micros() as f64) as u64;
                // Inline event: a 1M-arrival day used to box 1M closures
                // (each owning an `Rc<str>` clone) before the run began.
                sim.schedule_event_at(
                    SimTime(base_us + off),
                    PlatformEvent::Invoke { function: fid },
                );
            }
        }
    }
}

/// Counter snapshot at a day boundary (or run end); per-day metrics are
/// deltas between consecutive snapshots.
#[derive(Debug, Clone, Default)]
struct DaySnap {
    records: usize,
    cold_starts: u64,
    warm_starts: u64,
    restored_starts: u64,
    snapshots_created: u64,
    restore_us: u64,
    freshens_on_restore: u64,
    freshens_started: u64,
    freshens_completed: u64,
    freshens_wasted: u64,
    evictions: u64,
    evictions_idle: u64,
    evictions_pressure: u64,
    warm_kills: u64,
    queued_total: u64,
    queue_wait_us: u64,
    stale_freshen_aborts: u64,
    dropped_infeasible: u64,
    /// Peak within the slice ending at this snapshot (the world's peak
    /// tracker is reset to the current residency after each capture).
    peak_resident_mb: u64,
    /// Queue-depth peak and wait maximum within the slice (the hub's
    /// trackers are reset after each capture, like the residency peak).
    queue_peak_depth: u64,
    queue_wait_max_us: u64,
    resident_mb_us: u64,
    // The ledger accounts in f64 bytes; the per-day delta rounds AFTER
    // subtracting (round-then-subtract would change pinned digests), so
    // these two snapshot fields must stay floats. DaySnap is world-local
    // scratch — it is never merged across shards, only differenced.
    // simlint: allow(D003, snapshot holds the ledger's raw f64 bytes and is differenced then rounded)
    network_bytes: f64,
    // simlint: allow(D003, snapshot holds the ledger's raw f64 bytes and is differenced then rounded)
    network_bytes_saved: f64,
    executed: u64,
}

impl DaySnap {
    fn capture(sim: &PlatformSim, w: &mut World, apps: &[String]) -> DaySnap {
        w.seal_resident_accounting(sim.now());
        let (mut net, mut saved) = (0.0f64, 0.0f64);
        for app in apps {
            let acct = w.ledger.account(app);
            net += acct.network_bytes;
            saved += acct.network_bytes_saved;
        }
        let snap = DaySnap {
            records: w.metrics.count(),
            cold_starts: w.metrics.cold_starts,
            warm_starts: w.metrics.warm_starts,
            restored_starts: w.metrics.restored_starts,
            snapshots_created: w.metrics.snapshots_created,
            restore_us: w.metrics.restore_us,
            freshens_on_restore: w.metrics.freshens_on_restore,
            freshens_started: w.metrics.freshens_started,
            freshens_completed: w.metrics.freshens_completed,
            freshens_wasted: w.metrics.freshens_wasted,
            evictions: w.metrics.evictions,
            evictions_idle: w.metrics.evictions_idle,
            evictions_pressure: w.metrics.evictions_pressure,
            warm_kills: w.metrics.warm_kills,
            queued_total: w.metrics.queued_total,
            queue_wait_us: w.metrics.queue_wait_us,
            stale_freshen_aborts: w.metrics.stale_freshen_aborts,
            dropped_infeasible: w.metrics.dropped_infeasible,
            peak_resident_mb: w.metrics.peak_resident_mb,
            queue_peak_depth: w.metrics.queue_peak_depth,
            queue_wait_max_us: w.metrics.queue_wait_max_us,
            resident_mb_us: w.metrics.resident_mb_us,
            network_bytes: net,
            network_bytes_saved: saved,
            executed: sim.executed(),
        };
        // Per-day peaks: the next slice starts from the current residency
        // (and queue depth); the wait maximum restarts from zero.
        w.metrics.peak_resident_mb = w.resident_mb;
        w.metrics.queue_peak_depth = w.dispatch.len() as u64;
        w.metrics.queue_wait_max_us = 0;
        snap
    }
}

/// Replay one world — one app (per-app mode) or a whole shard's apps
/// (shared mode) — across one or more day slices, with pool + predictor
/// state carried over day boundaries. `days[d]` holds day `d`'s rows for
/// the SAME apps in the SAME order; `days[0]` is also the deployment
/// basis. Returns one [`MacroMetrics`] per day (`apps`/`functions`/
/// `chains` are attributed to day 0, so merging the days gives correct
/// cumulative totals).
pub fn replay_pool_days(
    days: &[AppRows],
    cfg: &ReplayCfg,
    world_seed: u64,
    day_minutes: usize,
) -> Vec<MacroMetrics> {
    assert!(!days.is_empty(), "replay needs at least one day");
    let day0 = &days[0];
    let mut config = cfg.base.clone();
    config.seed = world_seed;
    let mut w = World::new(config);
    // Replay is the one driver that churns through millions of
    // invocations per world: recycle slab slots so peak memory tracks
    // in-flight contexts, not cumulative arrivals. Must be set before
    // the first insert (the slab pins the mode at first use).
    w.invocations.set_recycle(true);
    w.auto_hist_predict = cfg.policy.histogram() && w.config.freshen.enabled;
    if cfg.trace_spans {
        w.obs = Tracer::enabled(cfg.span_cap, cfg.span_filter.clone());
    }
    w.metrics.windows.enabled = cfg.fn_windows;

    let mut store = Endpoint::new("store", Site::Remote);
    store.store.put("ID1", FETCH_BYTES, SimTime::ZERO);
    w.add_endpoint(store);

    let mut deps = Vec::with_capacity(day0.len());
    let mut jitters = Vec::with_capacity(day0.len());
    for (app, rows) in day0 {
        deps.push(deploy_and_warm(&mut w, app, rows, cfg));
        // The per-app jitter stream is derived from the app, not the
        // world, so per-app and shared replays of the same trace see the
        // same arrival instants.
        jitters.push(Rng::new(mix64(mix64(cfg.seed, app_hash(app)), JITTER_STREAM)));
    }

    let mut sim: PlatformSim = Sim::new();
    sim.max_events = 2_000_000_000;

    let app_names: Rc<Vec<String>> = Rc::new(day0.iter().map(|(a, _)| a.clone()).collect());
    let snaps: Rc<RefCell<Vec<DaySnap>>> = Rc::new(RefCell::new(Vec::new()));
    for (day, day_apps) in days.iter().enumerate() {
        debug_assert_eq!(
            day_apps.len(),
            day0.len(),
            "every day must replay the same apps"
        );
        let day_base_us = day as u64 * day_minutes as u64 * MINUTE.micros();
        if day > 0 {
            // Boundary snapshot: scheduled before this day's arrivals, so
            // at the boundary instant it fires first (FIFO sequencing).
            let snaps = Rc::clone(&snaps);
            let names = Rc::clone(&app_names);
            sim.schedule_at(SimTime(day_base_us), move |sim, w| {
                let snap = DaySnap::capture(sim, w, &names);
                snaps.borrow_mut().push(snap);
            });
        }
        for (i, (_, rows)) in day_apps.iter().enumerate() {
            let skip = if day == 0 { deps[i].warm } else { 0 };
            schedule_app_day(&mut sim, &deps[i], rows, skip, day_base_us, &mut jitters[i]);
        }
    }
    sim.run(&mut w);

    // Final snapshot covers the last day plus its drain tail. Every
    // boundary event has fired (the sim ran to quiescence), so this is
    // the only live handle.
    let last = DaySnap::capture(&sim, &mut w, &app_names);
    let mut bounds = Rc::try_unwrap(snaps)
        .expect("all day-boundary snapshot events fired")
        .into_inner();
    bounds.push(last);
    debug_assert_eq!(bounds.len(), days.len());

    let mut out = Vec::with_capacity(days.len());
    let mut prev = DaySnap::default();
    for (day, cur) in bounds.iter().enumerate() {
        let mut m = MacroMetrics::default();
        if day == 0 {
            m.apps = deps.len() as u64;
            m.functions = deps.iter().map(|d| d.functions).sum();
            m.chains = deps.iter().filter(|d| d.chained).count() as u64;
            m.chains_demoted = deps.iter().filter(|d| d.demoted).count() as u64;
        }
        m.invocations = (cur.records - prev.records) as u64;
        m.cold_starts = cur.cold_starts - prev.cold_starts;
        m.warm_starts = cur.warm_starts - prev.warm_starts;
        m.restored_starts = cur.restored_starts - prev.restored_starts;
        m.snapshots = cur.snapshots_created - prev.snapshots_created;
        m.restore_us = cur.restore_us - prev.restore_us;
        m.freshens_on_restore = cur.freshens_on_restore - prev.freshens_on_restore;
        m.freshens_started = cur.freshens_started - prev.freshens_started;
        m.freshens_completed = cur.freshens_completed - prev.freshens_completed;
        m.freshens_wasted = cur.freshens_wasted - prev.freshens_wasted;
        m.evictions = cur.evictions - prev.evictions;
        m.evictions_idle = cur.evictions_idle - prev.evictions_idle;
        m.evictions_pressure = cur.evictions_pressure - prev.evictions_pressure;
        m.warm_kills = cur.warm_kills - prev.warm_kills;
        m.queued_total = cur.queued_total - prev.queued_total;
        m.queue_wait_us = cur.queue_wait_us - prev.queue_wait_us;
        m.stale_freshen_aborts = cur.stale_freshen_aborts - prev.stale_freshen_aborts;
        m.dropped_infeasible = cur.dropped_infeasible - prev.dropped_infeasible;
        m.queue_peak_depth = cur.queue_peak_depth;
        m.queue_wait_max_us = cur.queue_wait_max_us;
        m.peak_resident_mb = cur.peak_resident_mb;
        m.resident_mb_us = cur.resident_mb_us - prev.resident_mb_us;
        m.network_bytes = (cur.network_bytes - prev.network_bytes).max(0.0).round() as u64;
        m.network_bytes_saved = (cur.network_bytes_saved - prev.network_bytes_saved)
            .max(0.0)
            .round() as u64;
        m.sim_events = cur.executed - prev.executed;
        for rec in &w.metrics.records()[prev.records..cur.records] {
            m.latency.record(rec.latency());
            m.freshen_hits += rec.freshen_hits as u64;
            m.freshen_total += (rec.freshen_hits + rec.freshen_misses) as u64;
        }
        out.push(m);
        prev = cur.clone();
    }
    // Spans and windows attach whole-run to the day-0 slice (like the
    // `apps`/`functions` identity fields): per-day attribution lives in
    // the span timestamps themselves. The group key is what makes the
    // merged stream partition-invariant — per-app worlds key by their
    // (globally unique) app name, shared pools by their (per-shard
    // unique) world seed, exactly mirroring each mode's determinism
    // contract.
    if w.obs.is_enabled() {
        let group = if day0.len() == 1 {
            day0[0].0.clone()
        } else {
            format!("pool-{world_seed:016x}")
        };
        let (events, dropped) = w.obs.drain(&w.registry.symbols);
        out[0].spans.push_group(group, events, dropped);
        // Filter misses are a separate tally from ring overflow: carry
        // the filtered count alongside the stream (it is summed on merge
        // but never folded into the span digest — a filtered event was
        // never part of the stream).
        out[0].spans.filtered = w.obs.take_filtered();
    }
    if w.metrics.windows.enabled {
        out[0].fn_windows = w.metrics.windows.take_finalized();
    }
    out
}

/// Replay one app's rows in its own world; returns its (mergeable)
/// metrics contribution. Deterministic in `(app, rows, cfg)` —
/// independent of every other app, of shard layout, and of worker
/// scheduling. This is the per-app pool mode's unit of work, unchanged
/// (byte-identically) through the memory-accounting refactor.
pub fn replay_app(app: &str, rows: &[TraceRow], cfg: &ReplayCfg) -> MacroMetrics {
    let days = [vec![(app.to_string(), rows.to_vec())]];
    let world_seed = mix64(cfg.seed, app_hash(app));
    replay_pool_days(&days, cfg, world_seed, 0)
        .pop()
        .expect("single-day replay yields one metrics slice")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::macrotrace::synth::{
        app_rows, app_rows_for_day, app_spec, SynthTraceCfg,
    };

    fn cfg_with(policy: PredictorPolicy, freshen: bool) -> ReplayCfg {
        let mut cfg = ReplayCfg::default();
        cfg.base.freshen.enabled = freshen;
        cfg.policy = policy;
        cfg.warmup_minutes = 5;
        cfg
    }

    fn synth() -> SynthTraceCfg {
        SynthTraceCfg {
            apps: 40,
            minutes: 20,
            seed: 99,
            ..SynthTraceCfg::default()
        }
    }

    #[test]
    fn replay_is_deterministic_per_app() {
        let s = synth();
        let rows = app_rows(&s, 3);
        let cfg = cfg_with(PredictorPolicy::Both, true);
        let a = replay_app("app-3", &rows, &cfg);
        let b = replay_app("app-3", &rows, &cfg);
        assert_eq!(a, b);
        assert_eq!(a.digest(), b.digest());
        assert_eq!(a.apps, 1);
        assert_eq!(a.functions, rows.len() as u64);
    }

    #[test]
    fn freshen_reduces_latency_on_an_orchestrated_app() {
        let s = synth();
        // Find an orchestrated app with real traffic.
        let idx = (0..s.apps)
            .find(|&i| {
                app_spec(&s, i).orchestrated
                    && app_rows(&s, i).iter().map(|r| r.invocations()).sum::<u64>() > 20
                    && app_rows(&s, i).len() > 1
            })
            .expect("synth population contains a busy orchestrated app");
        let rows = app_rows(&s, idx);
        let app = rows[0].app.clone();
        let off = replay_app(&app, &rows, &cfg_with(PredictorPolicy::None, false));
        let on = replay_app(&app, &rows, &cfg_with(PredictorPolicy::Both, true));
        assert_eq!(off.freshens_started, 0, "baseline must not freshen");
        assert!(on.freshens_completed > 0, "freshen ran");
        assert!(on.freshen_hits > 0, "freshen produced hits");
        // Same workload arrived on both (chain-driven totals match).
        assert_eq!(off.invocations, on.invocations);
        assert!(
            on.p50_ms() <= off.p50_ms(),
            "freshen p50 {} should not exceed baseline {}",
            on.p50_ms(),
            off.p50_ms()
        );
    }

    #[test]
    fn chain_policy_drives_head_only_and_hist_policy_drives_all_rows() {
        let s = synth();
        let idx = (0..s.apps)
            .find(|&i| app_spec(&s, i).orchestrated && app_rows(&s, i).len() > 2)
            .expect("orchestrated app with a >2-stage chain");
        let rows = app_rows(&s, idx);
        let app = rows[0].app.clone();
        let chain = replay_app(&app, &rows, &cfg_with(PredictorPolicy::Chain, true));
        let hist = replay_app(&app, &rows, &cfg_with(PredictorPolicy::Histogram, true));
        // Both replays process the full workload: under the chain policy
        // successors are invoked by triggers, under the histogram policy
        // by their own (mirrored) trace rows.
        assert_eq!(chain.invocations, hist.invocations);
        assert_eq!(chain.functions, hist.functions);
    }

    #[test]
    fn non_mirrored_chain_is_demoted_to_keep_variants_comparable() {
        let s = synth();
        let idx = (0..s.apps)
            .find(|&i| app_spec(&s, i).orchestrated && app_rows(&s, i).len() > 1)
            .expect("orchestrated app");
        let mut rows = app_rows(&s, idx);
        let app = rows[0].app.clone();
        // Real-CSV shape: a successor row whose counts do NOT mirror the
        // head's (e.g. a fan-out stage invoked more often).
        let last = rows.len() - 1;
        rows[last].counts[0] += 7;
        let chain = replay_app(&app, &rows, &cfg_with(PredictorPolicy::Chain, true));
        let none = replay_app(&app, &rows, &cfg_with(PredictorPolicy::None, false));
        assert_eq!(chain.chains, 0, "mismatched chain must not replay as a chain");
        assert_eq!(chain.chains_demoted, 1);
        assert_eq!(none.chains_demoted, 0, "policies without chains never demote");
        // The demoted replay drives every row independently, so the chain
        // variant processes the same volume as the baseline.
        assert_eq!(chain.invocations, none.invocations);
        // The untouched app really does chain under the same policy.
        let intact = app_rows(&s, idx);
        let chained = replay_app(&app, &intact, &cfg_with(PredictorPolicy::Chain, true));
        assert_eq!(chained.chains, 1);
        assert_eq!(chained.chains_demoted, 0);
    }

    #[test]
    fn empty_rows_yield_empty_metrics() {
        let cfg = cfg_with(PredictorPolicy::Both, true);
        let m = replay_app("ghost", &[], &cfg);
        assert_eq!(m.invocations, 0);
        assert_eq!(m.functions, 0);
        assert_eq!(m.apps, 1);
        assert!(m.latency.is_empty());
    }

    #[test]
    fn pool_mode_parses() {
        assert_eq!(PoolMode::parse("per-app"), Some(PoolMode::PerApp));
        assert_eq!(PoolMode::parse("per_app"), Some(PoolMode::PerApp));
        assert_eq!(PoolMode::parse("shared"), Some(PoolMode::Shared));
        assert_eq!(PoolMode::parse("bogus"), None);
        assert_eq!(PoolMode::default(), PoolMode::PerApp);
        for m in [PoolMode::PerApp, PoolMode::Shared] {
            assert_eq!(PoolMode::parse(m.as_str()), Some(m));
        }
    }

    #[test]
    fn shared_world_replays_apps_together_and_deterministically() {
        let s = synth();
        let apps: Vec<(String, Vec<TraceRow>)> = (0..6)
            .map(|i| (format!("app-{i}"), app_rows(&s, i)))
            .collect();
        let mut cfg = cfg_with(PredictorPolicy::Both, true);
        cfg.pool = PoolMode::Shared;
        let days = [apps.clone()];
        let seed = shared_world_seed(cfg.seed, 0);
        let a = replay_pool_days(&days, &cfg, seed, s.minutes).pop().unwrap();
        let b = replay_pool_days(&days, &cfg, seed, s.minutes).pop().unwrap();
        assert_eq!(a, b, "shared replay is deterministic");
        assert_eq!(a.apps, 6);
        let per_app_inv: u64 = apps
            .iter()
            .map(|(app, rows)| replay_app(app, rows, &cfg_with(PredictorPolicy::Both, true)).invocations)
            .sum();
        assert_eq!(
            a.invocations, per_app_inv,
            "shared pool replays the same arrival volume as per-app worlds"
        );
    }

    #[test]
    fn shared_pool_keeps_colliding_function_names_apart() {
        // The Azure dataset's HashFunction hashes the bare function name,
        // so two apps can carry the same function id. In a shared world
        // they must not alias onto one deployment (which would share warm
        // containers across tenants): qualified ids make the colliding
        // trace replay exactly like the same trace with distinct names.
        let mk_row = |app: &str, function: &str, counts: Vec<u32>| TraceRow {
            app: app.to_string(),
            function: function.to_string(),
            trigger: "http".to_string(),
            duration_ms: 25.0,
            memory_mb: 128,
            counts,
        };
        let colliding = [vec![
            ("a".to_string(), vec![mk_row("a", "run", vec![2, 1, 2])]),
            ("b".to_string(), vec![mk_row("b", "run", vec![1, 2, 1])]),
        ]];
        let distinct = [vec![
            ("a".to_string(), vec![mk_row("a", "run-a", vec![2, 1, 2])]),
            ("b".to_string(), vec![mk_row("b", "run-b", vec![1, 2, 1])]),
        ]];
        let mut cfg = cfg_with(PredictorPolicy::Both, true);
        cfg.pool = PoolMode::Shared;
        cfg.warmup_minutes = 0;
        let seed = shared_world_seed(cfg.seed, 0);
        let c = replay_pool_days(&colliding, &cfg, seed, 3).pop().unwrap();
        let d = replay_pool_days(&distinct, &cfg, seed, 3).pop().unwrap();
        assert_eq!(c.invocations, 9);
        assert_eq!(c.invocations, d.invocations);
        assert_eq!(
            (c.cold_starts, c.warm_starts),
            (d.cold_starts, d.warm_starts),
            "colliding names must behave exactly like distinct ones"
        );
    }

    #[test]
    fn snapshot_mitigation_restores_across_an_idle_gap_and_gates_the_digest() {
        // One function, a burst, a gap longer than the default 600 s idle
        // TTL, then a second burst: the baseline cold-starts the second
        // burst, the snapshot axis resumes it from a parked container.
        let row = TraceRow {
            app: "snap".to_string(),
            function: "f".to_string(),
            trigger: "http".to_string(),
            duration_ms: 25.0,
            memory_mb: 256,
            counts: {
                let mut c = vec![0u32; 16];
                c[0] = 3;
                c[15] = 3;
                c
            },
        };
        let mut base = cfg_with(PredictorPolicy::None, false);
        base.warmup_minutes = 0;
        let off = replay_app("snap", &[row.clone()], &base);
        assert_eq!(off.snapshots, 0);
        assert_eq!(off.restored_starts, 0);
        assert_eq!(off.restore_us, 0);
        assert!(
            !off.digest().contains(" sn="),
            "axis off keeps the legacy digest shape"
        );

        let mut snap_cfg = base.clone();
        snap_cfg.base.snapshot.enabled = true;
        let on = replay_app("snap", &[row.clone()], &snap_cfg);
        assert_eq!(on.invocations, off.invocations, "same arrival volume");
        assert!(on.snapshots >= 1, "idle expiry demoted instead of evicting");
        assert!(
            on.restored_starts >= 1,
            "the second burst resumed from the snapshot"
        );
        assert!(
            on.restored_starts <= on.snapshots,
            "every restore consumes a prior snapshot"
        );
        assert_eq!(
            on.cold_starts + on.warm_starts + on.restored_starts,
            on.invocations,
            "start kinds partition completions"
        );
        assert!(
            on.cold_starts < off.cold_starts,
            "restores displaced cold starts"
        );
        assert!(on.restore_us > 0, "restores paid their latency");
        assert!(on.digest().contains(" sn="), "suffix appears with the axis on");
        let again = replay_app("snap", &[row], &snap_cfg);
        assert_eq!(on, again, "the new axis replays deterministically");
    }

    #[test]
    fn multi_day_replay_carries_state_and_reports_per_day() {
        let s = SynthTraceCfg {
            apps: 8,
            minutes: 10,
            seed: 1234,
            ..SynthTraceCfg::default()
        };
        let mk_day = |day: usize| -> Vec<(String, Vec<TraceRow>)> {
            (0..s.apps)
                .map(|i| (format!("app-{i}"), app_rows_for_day(&s, i, day)))
                .collect()
        };
        let days: Vec<_> = (0..3).map(mk_day).collect();
        let cfg = cfg_with(PredictorPolicy::Both, true);
        let seed = shared_world_seed(cfg.seed, 0);
        let mut shared_cfg = cfg.clone();
        shared_cfg.pool = PoolMode::Shared;
        let per_day = replay_pool_days(&days, &shared_cfg, seed, s.minutes);
        assert_eq!(per_day.len(), 3);
        // Apps/functions are attributed once (day 0), so the cumulative
        // merge counts them once.
        assert_eq!(per_day[0].apps, s.apps as u64);
        assert_eq!(per_day[1].apps, 0);
        let mut cumulative = MacroMetrics::default();
        for d in &per_day {
            cumulative.merge(d);
        }
        assert_eq!(cumulative.apps, s.apps as u64);
        let expected: u64 = per_day.iter().map(|d| d.invocations).sum();
        assert_eq!(cumulative.invocations, expected);
        assert!(cumulative.invocations > 0, "the trace drove work");
        // Day 0 skips its warmup minutes; days 1+ replay their full
        // horizon (warmup is a day-0-only affair).
        assert!(per_day[1].invocations > 0, "day 1 saw arrivals");
        // Determinism across reruns.
        let again = replay_pool_days(&days, &shared_cfg, seed, s.minutes);
        assert_eq!(per_day, again);
    }
}
