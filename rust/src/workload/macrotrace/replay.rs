//! Per-app trace replay onto the full platform (`platform::World`).
//!
//! **Unit of replay = one application.** Each app runs in its own `World`
//! whose RNG stream is derived from `(run seed, hash(app))`, with all of
//! its functions deployed together (so chain prediction and per-app
//! isolation see the complete invocation sequence — the reason sharding
//! partitions by hash-of-app, never by row). Azure apps are isolated
//! tenants: containers are never shared across apps on the real platform
//! either, so per-app worlds change no semantics — and they are what makes
//! the merged metrics *provably* independent of the shard map. An app's
//! replay depends only on its own rows and the run seed; the merge
//! ([`MacroMetrics::merge`]) is a commutative sum of `u64` counters and
//! histogram bins. Shards 1/2/8, parallel 1/4 — same bytes out.
//!
//! Replay of one app:
//! 1. deploy every row as a paper-λ (`DataGet → Compute(duration) →
//!    DataPut`), wiring `orchestration` rows into an explicit chain
//!    (`InvokeNext` via the Step Functions trigger) when the predictor
//!    policy enables chains;
//! 2. bulk-warm the histogram/chain predictors from the first
//!    `warmup_minutes` of counts (no simulator events — the predictors'
//!    dedicated warmup path);
//! 3. expand the remaining per-minute counts lazily into `invoke`
//!    events (counts are the compact form; the event stream never
//!    materialises outside the wheel) and run the world to quiescence.

use std::hash::Hasher;

use crate::metrics::hist::LatencyHist;
use crate::netsim::link::Site;
use crate::platform::endpoint::Endpoint;
use crate::platform::exec::invoke;
use crate::platform::function::{Arg, FunctionSpec, Op};
use crate::platform::world::World;
use crate::simcore::Sim;
use crate::triggers::TriggerService;
use crate::util::config::Config;
use crate::util::fxhash::FxHasher;
use crate::util::rng::{mix64, Rng};
use crate::util::time::{SimDuration, SimTime};
use crate::workload::macrotrace::ingest::TraceRow;

/// One trace minute, in simulator microseconds.
pub const MINUTE: SimDuration = SimDuration(60_000_000);

/// Which prediction sources feed freshen during replay (the experiment's
/// ablation axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PredictorPolicy {
    /// No prediction at all (the freshen-off baseline).
    None,
    /// IAT-histogram predictions only; chains replay as independent rows.
    Histogram,
    /// Explicit-chain predictions only.
    Chain,
    /// Both sources (the paper's full system).
    Both,
}

impl PredictorPolicy {
    // User-facing string parsing lives on `experiments::azure_macro::
    // Variant` (the CLI surface); this enum stays a plain internal switch.
    fn histogram(&self) -> bool {
        matches!(self, PredictorPolicy::Histogram | PredictorPolicy::Both)
    }

    fn chain(&self) -> bool {
        matches!(self, PredictorPolicy::Chain | PredictorPolicy::Both)
    }
}

/// Replay configuration shared by every app of a run.
#[derive(Debug, Clone)]
pub struct ReplayCfg {
    /// Platform config template (freshen switch, pool sizing); the seed
    /// field is overwritten per app.
    pub base: Config,
    /// Run seed; app worlds derive their streams from `(seed, app)`.
    pub seed: u64,
    /// Leading minutes fed to the predictors instead of simulated.
    pub warmup_minutes: usize,
    pub policy: PredictorPolicy,
}

impl Default for ReplayCfg {
    fn default() -> ReplayCfg {
        let mut base = Config::default();
        // Match the e2e experiment's admission threshold so macro results
        // compare against the repo's headline numbers.
        base.freshen.min_confidence = 0.3;
        ReplayCfg {
            base,
            seed: 2020,
            warmup_minutes: 10,
            policy: PredictorPolicy::Both,
        }
    }
}

/// Merged replay metrics. Integer-only by design: merging is a
/// commutative, associative sum, so the result is byte-identical for any
/// partition of the same apps across shards/workers. (Latency percentiles
/// and rates are *derived* from these integers at report time.)
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MacroMetrics {
    pub apps: u64,
    pub functions: u64,
    pub invocations: u64,
    pub cold_starts: u64,
    pub warm_starts: u64,
    pub freshens_started: u64,
    pub freshens_completed: u64,
    pub freshens_wasted: u64,
    /// Freshen resource hits / total resource touches across invocations.
    pub freshen_hits: u64,
    pub freshen_total: u64,
    /// Network bytes billed / saved (rounded to integer bytes so merges
    /// stay order-independent — f64 addition is not associative).
    pub network_bytes: u64,
    pub network_bytes_saved: u64,
    /// Simulator events executed (replay throughput accounting).
    pub sim_events: u64,
    /// Apps replayed with an active explicit chain.
    pub chains: u64,
    /// Apps whose `orchestration` rows did NOT mirror the head's counts
    /// and were therefore replayed as independent rows (real-CSV safety:
    /// keeps every variant's invocation volume comparable).
    pub chains_demoted: u64,
    pub latency: LatencyHist,
}

impl MacroMetrics {
    /// Commutative merge (see type-level docs).
    pub fn merge(&mut self, other: &MacroMetrics) {
        self.apps += other.apps;
        self.functions += other.functions;
        self.invocations += other.invocations;
        self.cold_starts += other.cold_starts;
        self.warm_starts += other.warm_starts;
        self.freshens_started += other.freshens_started;
        self.freshens_completed += other.freshens_completed;
        self.freshens_wasted += other.freshens_wasted;
        self.freshen_hits += other.freshen_hits;
        self.freshen_total += other.freshen_total;
        self.network_bytes += other.network_bytes;
        self.network_bytes_saved += other.network_bytes_saved;
        self.sim_events += other.sim_events;
        self.chains += other.chains;
        self.chains_demoted += other.chains_demoted;
        self.latency.merge(&other.latency);
    }

    pub fn cold_start_rate(&self) -> f64 {
        if self.invocations == 0 {
            0.0
        } else {
            self.cold_starts as f64 / self.invocations as f64
        }
    }

    pub fn freshen_hit_rate(&self) -> f64 {
        if self.freshen_total == 0 {
            0.0
        } else {
            self.freshen_hits as f64 / self.freshen_total as f64
        }
    }

    /// Fraction of admitted freshens whose predicted invocation never
    /// arrived (the paper's wasted-work/billing concern).
    pub fn wasted_freshen_fraction(&self) -> f64 {
        if self.freshens_started == 0 {
            0.0
        } else {
            self.freshens_wasted as f64 / self.freshens_started as f64
        }
    }

    pub fn p50_ms(&self) -> f64 {
        self.latency.quantile_ms(50.0)
    }

    pub fn p99_ms(&self) -> f64 {
        self.latency.quantile_ms(99.0)
    }

    /// Canonical content fingerprint — the string the shard-determinism
    /// regression tests compare byte-for-byte.
    pub fn digest(&self) -> String {
        format!(
            "apps={} fns={} inv={} cold={} warm={} fs={} fc={} fw={} fh={}/{} \
             net={} saved={} ev={} ch={}/{} lat={:016x}",
            self.apps,
            self.functions,
            self.invocations,
            self.cold_starts,
            self.warm_starts,
            self.freshens_started,
            self.freshens_completed,
            self.freshens_wasted,
            self.freshen_hits,
            self.freshen_total,
            self.network_bytes,
            self.network_bytes_saved,
            self.sim_events,
            self.chains,
            self.chains_demoted,
            self.latency.digest(),
        )
    }
}

/// Stable 64-bit app identity (FxHash of the app name) — seeds the
/// per-app world and drives shard assignment.
pub fn app_hash(app: &str) -> u64 {
    let mut h = FxHasher::default();
    h.write(app.as_bytes());
    h.finish()
}

/// The 1 MB model-like object every replayed λ fetches (the paper's λ1
/// shape: constant-argument read of a hot object).
const FETCH_BYTES: f64 = 1e6;
const PUT_BYTES: f64 = 64.0 * 1024.0;

/// Replay one app's rows; returns its (mergeable) metrics contribution.
/// Deterministic in `(app, rows, cfg)` — independent of every other app,
/// of shard layout, and of worker scheduling.
pub fn replay_app(app: &str, rows: &[TraceRow], cfg: &ReplayCfg) -> MacroMetrics {
    let mut config = cfg.base.clone();
    config.seed = mix64(cfg.seed, app_hash(app));
    let world_seed = config.seed;
    let mut w = World::new(config);
    w.auto_hist_predict = cfg.policy.histogram() && w.config.freshen.enabled;

    let mut store = Endpoint::new("store", Site::Remote);
    store.store.put("ID1", FETCH_BYTES, SimTime::ZERO);
    w.add_endpoint(store);

    // Explicit chain: the app's `orchestration` rows, in row order.
    let chain: Vec<usize> = rows
        .iter()
        .enumerate()
        .filter(|(_, r)| r.trigger == "orchestration")
        .map(|(i, _)| i)
        .collect();
    // Chain replay drives only the head row and lets triggers produce the
    // successors, so it is only workload-preserving when every chain row
    // mirrors the head's counts (the synthesizer guarantees this; a real
    // CSV may not). A non-mirrored chain is DEMOTED to independent-row
    // replay — counted in `chains_demoted` — so every variant of the
    // benchmark replays the same invocation volume and the cross-variant
    // comparison stays honest.
    let mirrored = chain.len() > 1
        && chain
            .iter()
            .all(|&i| rows[i].counts == rows[chain[0]].counts);
    let chained = cfg.policy.chain() && mirrored;

    for (i, row) in rows.iter().enumerate() {
        let mut ops = vec![
            Op::DataGet {
                endpoint: "store".into(),
                creds: Arg::Const("CREDS".into()),
                object_id: Arg::Const("ID1".into()),
            },
            Op::Compute {
                duration: SimDuration::from_millis_f64(row.duration_ms),
            },
            Op::DataPut {
                endpoint: "store".into(),
                creds: Arg::Const("CREDS".into()),
                object_id: Arg::Const(format!("out-{i}")),
                bytes: PUT_BYTES,
            },
        ];
        if chained {
            if let Some(pos) = chain.iter().position(|&c| c == i) {
                if pos + 1 < chain.len() {
                    ops.push(Op::InvokeNext {
                        function: rows[chain[pos + 1]].function.clone(),
                        trigger: TriggerService::StepFunctions,
                    });
                }
            }
        }
        let mut spec = FunctionSpec::new(&row.function, app, ops);
        spec.memory_mb = row.memory_mb.max(64);
        w.deploy(spec);
    }
    if chained {
        let fns: Vec<String> = chain.iter().map(|&i| rows[i].function.clone()).collect();
        w.registry
            .register_chain(app, fns)
            .expect("chain functions were just deployed");
    }

    // Bulk predictor warmup from the leading minutes (no sim events).
    let horizon = rows.iter().map(|r| r.counts.len()).max().unwrap_or(0);
    let warm = cfg.warmup_minutes.min(horizon);
    if warm > 0 {
        // Only warm the predictor this policy will actually consult.
        if cfg.policy.histogram() {
            for row in rows {
                let w_counts = &row.counts[..warm.min(row.counts.len())];
                w.hist_pred.warm_from_minute_counts(
                    &row.function,
                    w_counts,
                    SimTime::ZERO,
                    MINUTE,
                );
            }
        }
        if chained {
            let head_warm: u64 = rows[chain[0]].counts[..warm.min(rows[chain[0]].counts.len())]
                .iter()
                .map(|&c| c as u64)
                .sum();
            if head_warm > 0 {
                for pair in chain.windows(2) {
                    w.chain_pred.warm_edge(
                        &rows[pair[0]].function,
                        &rows[pair[1]].function,
                        head_warm,
                        head_warm,
                    );
                }
            }
        }
    }

    // Rows the trace drives directly: everything, except that when the
    // chain is active only its head receives external arrivals (successor
    // counts mirror the head's and are produced by the chain itself).
    let driven: Vec<&TraceRow> = rows
        .iter()
        .enumerate()
        .filter(|(i, r)| {
            if chained && r.trigger == "orchestration" {
                *i == chain[0]
            } else {
                true
            }
        })
        .map(|(_, r)| r)
        .collect();

    let mut sim: Sim<World> = Sim::new();
    sim.max_events = 2_000_000_000;
    let mut jitter = Rng::new(mix64(world_seed, 0xA11C_E500));
    for row in &driven {
        for (m, &c) in row.counts.iter().enumerate().skip(warm) {
            if c == 0 {
                continue;
            }
            let base_us = m as u64 * MINUTE.micros();
            for j in 0..c as u64 {
                let off = ((j as f64 + jitter.f64()) / c as f64
                    * MINUTE.micros() as f64) as u64;
                let f = row.function.clone();
                sim.schedule_at(SimTime(base_us + off), move |sim, w| {
                    invoke(sim, w, &f);
                });
            }
        }
    }
    sim.run(&mut w);

    let mut out = MacroMetrics {
        apps: 1,
        functions: rows.len() as u64,
        invocations: w.metrics.count() as u64,
        cold_starts: w.metrics.cold_starts,
        warm_starts: w.metrics.warm_starts,
        freshens_started: w.metrics.freshens_started,
        freshens_completed: w.metrics.freshens_completed,
        freshens_wasted: w.metrics.freshens_wasted,
        sim_events: sim.executed(),
        chains: u64::from(chained),
        chains_demoted: u64::from(cfg.policy.chain() && chain.len() > 1 && !mirrored),
        ..MacroMetrics::default()
    };
    let (hits, total) = w.metrics.freshen_hit_counts();
    out.freshen_hits = hits;
    out.freshen_total = total;
    let acct = w.ledger.account(app);
    out.network_bytes = acct.network_bytes.round() as u64;
    out.network_bytes_saved = acct.network_bytes_saved.round() as u64;
    for rec in w.metrics.records() {
        out.latency.record(rec.latency());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::macrotrace::synth::{app_rows, app_spec, SynthTraceCfg};

    fn cfg_with(policy: PredictorPolicy, freshen: bool) -> ReplayCfg {
        let mut cfg = ReplayCfg::default();
        cfg.base.freshen.enabled = freshen;
        cfg.policy = policy;
        cfg.warmup_minutes = 5;
        cfg
    }

    fn synth() -> SynthTraceCfg {
        SynthTraceCfg {
            apps: 40,
            minutes: 20,
            seed: 99,
            ..SynthTraceCfg::default()
        }
    }

    #[test]
    fn replay_is_deterministic_per_app() {
        let s = synth();
        let rows = app_rows(&s, 3);
        let cfg = cfg_with(PredictorPolicy::Both, true);
        let a = replay_app("app-3", &rows, &cfg);
        let b = replay_app("app-3", &rows, &cfg);
        assert_eq!(a, b);
        assert_eq!(a.digest(), b.digest());
        assert_eq!(a.apps, 1);
        assert_eq!(a.functions, rows.len() as u64);
    }

    #[test]
    fn freshen_reduces_latency_on_an_orchestrated_app() {
        let s = synth();
        // Find an orchestrated app with real traffic.
        let idx = (0..s.apps)
            .find(|&i| {
                app_spec(&s, i).orchestrated
                    && app_rows(&s, i).iter().map(|r| r.invocations()).sum::<u64>() > 20
                    && app_rows(&s, i).len() > 1
            })
            .expect("synth population contains a busy orchestrated app");
        let rows = app_rows(&s, idx);
        let app = rows[0].app.clone();
        let off = replay_app(&app, &rows, &cfg_with(PredictorPolicy::None, false));
        let on = replay_app(&app, &rows, &cfg_with(PredictorPolicy::Both, true));
        assert_eq!(off.freshens_started, 0, "baseline must not freshen");
        assert!(on.freshens_completed > 0, "freshen ran");
        assert!(on.freshen_hits > 0, "freshen produced hits");
        // Same workload arrived on both (chain-driven totals match).
        assert_eq!(off.invocations, on.invocations);
        assert!(
            on.p50_ms() <= off.p50_ms(),
            "freshen p50 {} should not exceed baseline {}",
            on.p50_ms(),
            off.p50_ms()
        );
    }

    #[test]
    fn chain_policy_drives_head_only_and_hist_policy_drives_all_rows() {
        let s = synth();
        let idx = (0..s.apps)
            .find(|&i| app_spec(&s, i).orchestrated && app_rows(&s, i).len() > 2)
            .expect("orchestrated app with a >2-stage chain");
        let rows = app_rows(&s, idx);
        let app = rows[0].app.clone();
        let chain = replay_app(&app, &rows, &cfg_with(PredictorPolicy::Chain, true));
        let hist = replay_app(&app, &rows, &cfg_with(PredictorPolicy::Histogram, true));
        // Both replays process the full workload: under the chain policy
        // successors are invoked by triggers, under the histogram policy
        // by their own (mirrored) trace rows.
        assert_eq!(chain.invocations, hist.invocations);
        assert_eq!(chain.functions, hist.functions);
    }

    #[test]
    fn non_mirrored_chain_is_demoted_to_keep_variants_comparable() {
        let s = synth();
        let idx = (0..s.apps)
            .find(|&i| app_spec(&s, i).orchestrated && app_rows(&s, i).len() > 1)
            .expect("orchestrated app");
        let mut rows = app_rows(&s, idx);
        let app = rows[0].app.clone();
        // Real-CSV shape: a successor row whose counts do NOT mirror the
        // head's (e.g. a fan-out stage invoked more often).
        let last = rows.len() - 1;
        rows[last].counts[0] += 7;
        let chain = replay_app(&app, &rows, &cfg_with(PredictorPolicy::Chain, true));
        let none = replay_app(&app, &rows, &cfg_with(PredictorPolicy::None, false));
        assert_eq!(chain.chains, 0, "mismatched chain must not replay as a chain");
        assert_eq!(chain.chains_demoted, 1);
        assert_eq!(none.chains_demoted, 0, "policies without chains never demote");
        // The demoted replay drives every row independently, so the chain
        // variant processes the same volume as the baseline.
        assert_eq!(chain.invocations, none.invocations);
        // The untouched app really does chain under the same policy.
        let intact = app_rows(&s, idx);
        let chained = replay_app(&app, &intact, &cfg_with(PredictorPolicy::Chain, true));
        assert_eq!(chained.chains, 1);
        assert_eq!(chained.chains_demoted, 0);
    }

    #[test]
    fn empty_rows_yield_empty_metrics() {
        let cfg = cfg_with(PredictorPolicy::Both, true);
        let m = replay_app("ghost", &[], &cfg);
        assert_eq!(m.invocations, 0);
        assert_eq!(m.functions, 0);
        assert_eq!(m.apps, 1);
        assert!(m.latency.is_empty());
    }
}
