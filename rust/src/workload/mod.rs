//! Workload synthesis and trace handling.
//!
//! - [`azure`] — a synthetic application population calibrated to the
//!   published statistics of the Azure Functions trace (Shahrad et al.
//!   [9]), which Figure 2 is drawn from.
//! - [`generator`] — arrival processes (Poisson, periodic-with-jitter,
//!   bursty) used to drive the platform in benches and examples.
//! - [`trace`] — JSON-lines trace records: write traces out, replay them in.

pub mod azure;
pub mod generator;
pub mod trace;
