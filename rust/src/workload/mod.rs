//! Workload synthesis and trace handling.
//!
//! - [`azure`] — a synthetic application population calibrated to the
//!   published statistics of the Azure Functions trace (Shahrad et al.
//!   [9]), which Figure 2 is drawn from.
//! - [`generator`] — arrival processes (Poisson, periodic-with-jitter,
//!   bursty) used to drive the platform in benches and examples.
//! - [`trace`] — JSON-lines trace records: write traces out, replay them
//!   in (streaming via [`trace::TraceReader`]).
//! - [`macrotrace`] — the Azure-trace macro benchmark: streaming CSV
//!   ingestion, offline trace synthesis, per-app platform replay, and
//!   deterministic hash-of-app sharding.

pub mod azure;
pub mod generator;
pub mod macrotrace;
pub mod trace;
