//! Minimal benchmarking harness (offline `criterion` substitute).
//!
//! Used by every target in `rust/benches/` (declared `harness = false`).
//! Reports per-iteration wall time with warmup, mean, p50, and min —
//! enough to drive the §Perf iteration loop and to print the paper-table
//! regeneration timings alongside the tables themselves.

use std::time::{Duration, Instant};

/// Timing result for one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "bench {:<38} iters={:<4} mean={:>12?} p50={:>12?} min={:>12?} max={:>12?}",
            self.name, self.iters, self.mean, self.p50, self.min, self.max
        );
    }

    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }
}

/// Run `f` `iters` times (after `warmup` throwaway runs) and report.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort();
    let total: Duration = samples.iter().sum();
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean: total / iters as u32,
        p50: samples[iters / 2],
        min: samples[0],
        max: samples[iters - 1],
    };
    r.print();
    r
}

/// Time one execution of `f`, returning `(result, elapsed)`.
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let v = f();
    (v, t0.elapsed())
}

/// Throughput helper: ops per second given work count and duration.
pub fn throughput(ops: u64, elapsed: Duration) -> f64 {
    ops as f64 / elapsed.as_secs_f64().max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_ordered_stats() {
        let r = bench("noop", 1, 16, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(r.iters, 16);
        assert!(r.min <= r.p50 && r.p50 <= r.max);
    }

    #[test]
    fn throughput_math() {
        assert!((throughput(100, Duration::from_secs(2)) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, d) = time_once(|| 42);
        assert_eq!(v, 42);
        assert!(d >= Duration::ZERO);
    }
}
