//! Minimal benchmarking harness (offline `criterion` substitute).
//!
//! Used by every target in `rust/benches/` (declared `harness = false`).
//! Reports per-iteration wall time with warmup, mean, p50, and min —
//! enough to drive the §Perf iteration loop and to print the paper-table
//! regeneration timings alongside the tables themselves.
//!
//! [`Snapshot`] persists a bench run as JSON when `BENCH_SNAPSHOT=<path>`
//! is set, so CI can commit `BENCH_*.json` performance baselines (the
//! ROADMAP raw-replay-speed item) instead of scraping stdout.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// Timing result for one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "bench {:<38} iters={:<4} mean={:>12?} p50={:>12?} min={:>12?} max={:>12?}",
            self.name, self.iters, self.mean, self.p50, self.min, self.max
        );
    }

    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }
}

/// Run `f` `iters` times (after `warmup` throwaway runs) and report.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort();
    let total: Duration = samples.iter().sum();
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean: total / iters as u32,
        p50: samples[iters / 2],
        min: samples[0],
        max: samples[iters - 1],
    };
    r.print();
    r
}

/// Time one execution of `f`, returning `(result, elapsed)`.
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let v = f();
    (v, t0.elapsed())
}

/// Throughput helper: ops per second given work count and duration.
pub fn throughput(ops: u64, elapsed: Duration) -> f64 {
    ops as f64 / elapsed.as_secs_f64().max(1e-12)
}

/// Environment variable naming the snapshot output file. When unset,
/// benches only print; when set, they also persist a [`Snapshot`].
pub const SNAPSHOT_ENV: &str = "BENCH_SNAPSHOT";

/// Schema tag stamped into every snapshot file, bumped on layout change.
pub const SNAPSHOT_SCHEMA: &str = "freshen-bench-snapshot/1";

/// Accumulates one bench binary's measurements and serializes them as a
/// stable JSON document. Durations are integer nanoseconds so snapshots
/// diff cleanly; derived rates keep their float precision.
#[derive(Debug, Clone)]
pub struct Snapshot {
    bench: String,
    results: Vec<Json>,
}

impl Snapshot {
    pub fn new(bench: &str) -> Snapshot {
        Snapshot {
            bench: bench.to_string(),
            results: Vec::new(),
        }
    }

    /// Record a throughput measurement: `ops` operations in `elapsed`.
    pub fn rate(&mut self, name: &str, ops: u64, elapsed: Duration) {
        self.results.push(Json::obj(vec![
            ("name", Json::str(name)),
            ("kind", Json::str("rate")),
            ("ops", Json::num(ops as f64)),
            ("elapsed_ns", Json::num(elapsed.as_nanos() as f64)),
            ("per_sec", Json::num(throughput(ops, elapsed))),
        ]));
    }

    /// Record a [`BenchResult`] distribution (iters, mean/p50/min/max).
    pub fn stats(&mut self, r: &BenchResult) {
        self.results.push(Json::obj(vec![
            ("name", Json::str(&r.name)),
            ("kind", Json::str("stats")),
            ("iters", Json::num(r.iters as f64)),
            ("mean_ns", Json::num(r.mean.as_nanos() as f64)),
            ("p50_ns", Json::num(r.p50.as_nanos() as f64)),
            ("min_ns", Json::num(r.min.as_nanos() as f64)),
            ("max_ns", Json::num(r.max.as_nanos() as f64)),
        ]));
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::str(SNAPSHOT_SCHEMA)),
            ("bench", Json::str(&self.bench)),
            ("results", Json::Arr(self.results.clone())),
        ])
    }

    pub fn write_to(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().pretty())
    }

    /// Persist to the file named by [`SNAPSHOT_ENV`], if set. Returns the
    /// path written so the bench can mention it in its output.
    pub fn write_if_requested(&self) -> std::io::Result<Option<PathBuf>> {
        match std::env::var_os(SNAPSHOT_ENV) {
            Some(p) => {
                let path = PathBuf::from(p);
                self.write_to(&path)?;
                Ok(Some(path))
            }
            None => Ok(None),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_ordered_stats() {
        let r = bench("noop", 1, 16, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(r.iters, 16);
        assert!(r.min <= r.p50 && r.p50 <= r.max);
    }

    #[test]
    fn throughput_math() {
        assert!((throughput(100, Duration::from_secs(2)) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, d) = time_once(|| 42);
        assert_eq!(v, 42);
        assert!(d >= Duration::ZERO);
    }

    #[test]
    fn snapshot_roundtrips_through_json() {
        let mut snap = Snapshot::new("unit");
        snap.rate("replay", 2_000, Duration::from_millis(4));
        snap.stats(&BenchResult {
            name: "transfer".to_string(),
            iters: 8,
            mean: Duration::from_nanos(1_500),
            p50: Duration::from_nanos(1_400),
            min: Duration::from_nanos(1_000),
            max: Duration::from_nanos(3_000),
        });
        let parsed = Json::parse(&snap.to_json().pretty()).expect("snapshot parses");
        assert_eq!(parsed.str_or("schema", ""), SNAPSHOT_SCHEMA);
        assert_eq!(parsed.str_or("bench", ""), "unit");
        let results = parsed.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].str_or("kind", ""), "rate");
        assert_eq!(results[0].u64_or("ops", 0), 2_000);
        assert_eq!(results[0].u64_or("elapsed_ns", 0), 4_000_000);
        assert!((results[0].f64_or("per_sec", 0.0) - 500_000.0).abs() < 1e-6);
        assert_eq!(results[1].str_or("kind", ""), "stats");
        assert_eq!(results[1].u64_or("iters", 0), 8);
        assert_eq!(results[1].u64_or("mean_ns", 0), 1_500);
        assert_eq!(results[1].u64_or("max_ns", 0), 3_000);
    }

    #[test]
    fn snapshot_writes_and_reparses_from_disk() {
        let path = std::env::temp_dir().join("freshen-bench-snapshot-test.json");
        let mut snap = Snapshot::new("disk");
        snap.rate("x", 10, Duration::from_micros(5));
        snap.write_to(&path).expect("snapshot written");
        let text = std::fs::read_to_string(&path).expect("snapshot readable");
        let parsed = Json::parse(&text).expect("snapshot parses");
        assert_eq!(parsed.str_or("bench", ""), "disk");
        assert_eq!(
            parsed.get("results").unwrap().as_arr().unwrap()[0].u64_or("ops", 0),
            10
        );
        let _ = std::fs::remove_file(&path);
    }
}
