//! Test utilities: the in-repo property-testing harness.
//!
//! `proptest` is not in the offline vendor set, so [`prop`] provides the
//! subset we need: seeded generators, a many-cases runner with failing-seed
//! reporting, and simple shrinking over integer parameters. Coordinator
//! invariants (routing, batching, fr_state) use it from `rust/tests/`.

pub mod bench;
pub mod prop;
