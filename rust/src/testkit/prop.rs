//! A small property-testing harness (offline `proptest` substitute).
//!
//! Usage (`no_run`: doctest binaries lack the xla rpath in this image):
//! ```no_run
//! use freshen_rs::testkit::prop::{forall, Gen};
//! forall("addition commutes", 200, |g| {
//!     let a = g.u64(0, 1000);
//!     let b = g.u64(0, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//!
//! Each case derives its inputs from a deterministic per-case seed; on
//! panic the harness reports the case index and seed so the failure can be
//! replayed with [`replay`].

use crate::util::rng::Rng;

/// Per-case input generator.
pub struct Gen {
    rng: Rng,
    /// Log of drawn values, printed on failure.
    log: Vec<String>,
}

impl Gen {
    pub fn new(seed: u64) -> Gen {
        Gen {
            rng: Rng::new(seed),
            log: Vec::new(),
        }
    }

    pub fn u64(&mut self, lo: u64, hi_inclusive: u64) -> u64 {
        let v = self.rng.range(lo, hi_inclusive + 1);
        self.log.push(format!("u64[{lo},{hi_inclusive}] = {v}"));
        v
    }

    pub fn usize(&mut self, lo: usize, hi_inclusive: usize) -> usize {
        self.u64(lo as u64, hi_inclusive as u64) as usize
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        let v = self.rng.uniform(lo, hi);
        self.log.push(format!("f64[{lo},{hi}] = {v}"));
        v
    }

    pub fn bool(&mut self, p: f64) -> bool {
        let v = self.rng.bernoulli(p);
        self.log.push(format!("bool({p}) = {v}"));
        v
    }

    pub fn choice<'a, T: std::fmt::Debug>(&mut self, xs: &'a [T]) -> &'a T {
        let v = self.rng.choice(xs);
        self.log.push(format!("choice = {v:?}"));
        v
    }

    /// A vector of length in `[0, max_len]` with elements from `f`.
    pub fn vec<T>(&mut self, max_len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let n = self.usize(0, max_len);
        (0..n).map(|_| f(self)).collect()
    }

    /// Raw access for generators not covered above.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `cases` seeded property cases; panics with the failing case's seed
/// and drawn-value log.
pub fn forall(name: &str, cases: u64, mut prop: impl FnMut(&mut Gen)) {
    let base = fnv(name);
    for i in 0..cases {
        let seed = base ^ i.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut g = Gen::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut g);
        }));
        if let Err(e) = result {
            eprintln!(
                "property '{name}' failed at case {i} (seed {seed:#x})\ndrawn values:\n  {}",
                g.log.join("\n  ")
            );
            std::panic::resume_unwind(e);
        }
    }
}

/// Replay one failing case by seed.
pub fn replay(seed: u64, mut prop: impl FnMut(&mut Gen)) {
    let mut g = Gen::new(seed);
    prop(&mut g);
}

fn fnv(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_all_cases() {
        let mut count = 0;
        forall("counting", 50, |_g| {
            count += 1;
        });
        assert_eq!(count, 50);
    }

    #[test]
    fn cases_are_deterministic() {
        let mut first = Vec::new();
        forall("det", 10, |g| first.push(g.u64(0, 1_000_000)));
        let mut second = Vec::new();
        forall("det", 10, |g| second.push(g.u64(0, 1_000_000)));
        assert_eq!(first, second);
    }

    #[test]
    #[should_panic]
    fn failures_propagate() {
        forall("fails", 10, |g| {
            let v = g.u64(0, 100);
            assert!(v > 1_000, "always fails");
        });
    }

    #[test]
    fn generators_respect_bounds() {
        forall("bounds", 200, |g| {
            let v = g.u64(5, 10);
            assert!((5..=10).contains(&v));
            let f = g.f64(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
            let xs = g.vec(8, |g| g.usize(0, 3));
            assert!(xs.len() <= 8);
            assert!(xs.iter().all(|&x| x <= 3));
        });
    }

    /// The timing wheel must replay the reference binary heap event for
    /// event — identical `(timestamp, seq)` pop order, identical cancel
    /// results (including cancel-after-fire and double-cancel), identical
    /// peeks and lengths — across randomized schedule/cancel/pop/peek
    /// workloads spanning immediates, every wheel level, and the overflow.
    #[test]
    fn prop_timing_wheel_matches_reference_heap_event_for_event() {
        use crate::simcore::wheel::{BinaryHeapQueue, EventQueue, TimingWheel};
        use crate::util::time::SimTime;

        forall("wheel == heap", 60, |g| {
            let mut wheel: TimingWheel<()> = TimingWheel::new();
            let mut heap: BinaryHeapQueue<()> = BinaryHeapQueue::new();
            let mut seq = 0u64;
            let mut now = 0u64; // timestamp of the last popped event
            let mut scheduled: Vec<u64> = Vec::new();
            let mut fired: Vec<u64> = Vec::new();
            let ops = g.usize(20, 300);
            for _ in 0..ops {
                match g.usize(0, 99) {
                    // 60%: schedule — immediates, near, mid, far/overflow.
                    0..=59 => {
                        let delta = match g.usize(0, 3) {
                            0 => 0, // same-timestamp FIFO
                            1 => g.u64(1, 100),
                            2 => g.u64(100, 1_000_000),
                            _ => g.u64(1_000_000, 1u64 << 44),
                        };
                        let at = SimTime(now + delta);
                        wheel.insert(at, seq, ());
                        heap.insert(at, seq, ());
                        scheduled.push(seq);
                        seq += 1;
                    }
                    // 15%: cancel — live, already-fired, or bogus ids.
                    60..=74 => {
                        let target = if !scheduled.is_empty() && g.bool(0.6) {
                            scheduled[g.usize(0, scheduled.len() - 1)]
                        } else if !fired.is_empty() && g.bool(0.7) {
                            // cancel-after-fire must be a false no-op
                            fired[g.usize(0, fired.len() - 1)]
                        } else {
                            seq + 1_000 // never scheduled
                        };
                        assert_eq!(
                            wheel.cancel(target),
                            heap.cancel(target),
                            "cancel({target}) diverged"
                        );
                    }
                    // 10%: peek (exercises the run_until cursor path).
                    75..=84 => {
                        assert_eq!(wheel.peek_at(), heap.peek_at());
                    }
                    // 25%: pop.
                    _ => {
                        let a = wheel.pop().map(|(at, s, _)| (at, s));
                        let b = heap.pop().map(|(at, s, _)| (at, s));
                        assert_eq!(a, b, "pop order diverged");
                        if let Some((at, s)) = a {
                            assert!(at.micros() >= now, "time went backwards");
                            now = at.micros();
                            fired.push(s);
                            scheduled.retain(|&x| x != s);
                        }
                    }
                }
                assert_eq!(wheel.len(), heap.len());
            }
            // Drain: the tails must agree exactly too.
            loop {
                let a = wheel.pop().map(|(at, s, _)| (at, s));
                let b = heap.pop().map(|(at, s, _)| (at, s));
                assert_eq!(a, b, "drain order diverged");
                match a {
                    Some((at, _)) => {
                        assert!(at.micros() >= now);
                        now = at.micros();
                    }
                    None => break,
                }
            }
            assert_eq!(wheel.len(), 0);
        });
    }
}
