//! The serving engine's remote datastore.
//!
//! An in-process object store whose access latencies come from the same
//! fluid TCP model the simulator uses ([`crate::netsim`]) — but here they
//! are *slept* for real (scaled by `time_scale` so tests stay fast). The
//! connection object carries genuine state: idle decay means a connection
//! that sat unused really is slower until warmed, which is exactly what
//! the freshen thread fixes ahead of requests.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::netsim::cc::CongestionControl;
use crate::netsim::link::Link;
use crate::netsim::tcp::{Connection, TransferDirection};
use crate::netsim::warm::{warm_cwnd, CwndHistory, WarmPolicy};
use crate::util::rng::Rng;
use crate::util::time::SimTime;

struct Inner {
    objects: HashMap<String, (u64, f64)>, // id -> (version, bytes)
    conn: Connection,
    rng: Rng,
    history: CwndHistory,
    pub gets: u64,
    pub puts: u64,
}

/// Thread-safe store with latency injection.
pub struct LatencyStore {
    inner: Mutex<Inner>,
    epoch: Instant,
    /// Real seconds slept per simulated second (0.01 -> 100x faster).
    pub time_scale: f64,
}

impl LatencyStore {
    pub fn new(link: Link, seed: u64, time_scale: f64) -> LatencyStore {
        LatencyStore {
            inner: Mutex::new(Inner {
                objects: HashMap::new(),
                conn: Connection::new(link, CongestionControl::Cubic),
                rng: Rng::new(seed),
                history: CwndHistory::new(),
                gets: 0,
                puts: 0,
            }),
            epoch: Instant::now(),
            time_scale,
        }
    }

    /// Simulated "now": real elapsed time mapped back to full-rate time,
    /// so connection idle decay happens at the modelled rate.
    fn sim_now(&self) -> SimTime {
        let real = self.epoch.elapsed().as_secs_f64();
        SimTime((real / self.time_scale * 1e6) as u64)
    }

    fn sleep_scaled(&self, sim_seconds: f64) {
        let real = sim_seconds * self.time_scale;
        if real > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(real));
        }
    }

    /// Seed an object without latency (setup).
    pub fn seed_object(&self, id: &str, bytes: f64) {
        let mut g = self.inner.lock().unwrap();
        let v = g.objects.get(id).map(|(v, _)| v + 1).unwrap_or(1);
        g.objects.insert(id.to_string(), (v, bytes));
    }

    /// Ensure the connection is live (freshen's `EnsureConnection`):
    /// keepalive or (re)establish. Returns the simulated seconds spent.
    pub fn ensure_connection(&self) -> f64 {
        let now = self.sim_now();
        let spent;
        {
            let g = &mut *self.inner.lock().unwrap();
            let mut t = 0.0;
            match g.conn.state {
                crate::netsim::tcp::ConnState::Established => {
                    let (d, alive) = g.conn.keepalive(now, &mut g.rng);
                    t += d.as_secs_f64();
                    if !alive {
                        t += g.conn.connect(now, &mut g.rng).as_secs_f64();
                    }
                }
                _ => {
                    t += g.conn.connect(now, &mut g.rng).as_secs_f64();
                }
            }
            spent = t;
        }
        self.sleep_scaled(spent);
        spent
    }

    /// Warm the upload window toward `anticipated_bytes` (freshen's
    /// `WarmCwnd`). Returns simulated seconds spent probing.
    pub fn warm(&self, anticipated_bytes: f64) -> f64 {
        self.ensure_connection();
        let now = self.sim_now();
        let spent;
        {
            let g = &mut *self.inner.lock().unwrap();
            let (_outcome, probe) = warm_cwnd(
                &mut g.conn,
                TransferDirection::Upload,
                anticipated_bytes,
                &WarmPolicy::default(),
                &mut g.history,
                now,
                &mut g.rng,
            );
            // Symmetric warm for downloads too (model fetches).
            let (_o2, _p2) = warm_cwnd(
                &mut g.conn,
                TransferDirection::Download,
                anticipated_bytes,
                &WarmPolicy::default(),
                &mut g.history,
                now,
                &mut g.rng,
            );
            spent = probe.as_secs_f64();
        }
        self.sleep_scaled(spent);
        spent
    }

    /// Fetch an object, paying connection + transfer latency for real.
    /// Returns `(version, bytes)` or `None` when missing.
    pub fn get(&self, id: &str) -> Option<(u64, f64)> {
        let now = self.sim_now();
        let (spent, found) = {
            let g = &mut *self.inner.lock().unwrap();
            g.gets += 1;
            let mut t = usable(&mut g.conn, &mut g.rng, now);
            let found = g.objects.get(id).copied();
            let resp_bytes = found.map(|(_, b)| b).unwrap_or(256.0);
            t += g
                .conn
                .request_response(now, &mut g.rng, 256.0, resp_bytes, 1e-3)
                .as_secs_f64();
            (t, found)
        };
        self.sleep_scaled(spent);
        found
    }

    /// Write an object, paying upload latency (benefits from warming).
    pub fn put(&self, id: &str, bytes: f64) -> u64 {
        let now = self.sim_now();
        let (spent, version) = {
            let g = &mut *self.inner.lock().unwrap();
            g.puts += 1;
            let mut t = usable(&mut g.conn, &mut g.rng, now);
            t += g.conn.send_with_ack(now, &mut g.rng, bytes, 1e-3).as_secs_f64();
            let v = g.objects.get(id).map(|(v, _)| v + 1).unwrap_or(1);
            g.objects.insert(id.to_string(), (v, bytes));
            (t, v)
        };
        self.sleep_scaled(spent);
        version
    }

    /// Current store op counters `(gets, puts)`.
    pub fn counters(&self) -> (u64, u64) {
        let g = self.inner.lock().unwrap();
        (g.gets, g.puts)
    }

    /// Upload cwnd right now (reporting: shows the warming effect).
    pub fn upload_cwnd(&self) -> f64 {
        self.inner
            .lock()
            .unwrap()
            .conn
            .cwnd(TransferDirection::Upload)
    }
}

/// Function-side connection use without a liveness check (see
/// `platform::exec::usable_connection` for the simulator twin).
fn usable(conn: &mut Connection, rng: &mut Rng, now: SimTime) -> f64 {
    use crate::netsim::tcp::ConnState;
    let mut t = 0.0;
    let dead = match conn.state {
        ConnState::Established => {
            if conn.idle_expired(now) {
                conn.kill();
                t += conn.rto();
                true
            } else {
                false
            }
        }
        _ => true,
    };
    if dead {
        t += conn.connect(now, rng).as_secs_f64();
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::link::Site;

    fn store() -> LatencyStore {
        // 1000x time compression so tests run in ms.
        LatencyStore::new(Site::Remote.link(), 7, 0.001)
    }

    #[test]
    fn get_put_roundtrip_with_latency() {
        let s = store();
        s.seed_object("model", 1e6);
        let t0 = Instant::now();
        let got = s.get("model").unwrap();
        assert_eq!(got.0, 1);
        assert_eq!(got.1, 1e6);
        // Paid some (scaled) latency: >= 50ms RTT * 0.001 = 50us.
        assert!(t0.elapsed() > Duration::from_micros(10));
        let v = s.put("out", 64.0 * 1024.0);
        assert_eq!(v, 1);
        assert_eq!(s.counters(), (1, 1));
    }

    #[test]
    fn missing_object_is_none_but_still_costs() {
        let s = store();
        assert!(s.get("ghost").is_none());
        assert_eq!(s.counters(), (1, 0));
    }

    #[test]
    fn warm_grows_upload_window() {
        let s = store();
        s.ensure_connection();
        let before = s.upload_cwnd();
        s.warm(8e6);
        assert!(s.upload_cwnd() > 4.0 * before);
    }

    #[test]
    fn warmed_put_is_faster() {
        let big = 5e6;
        let cold = store();
        cold.seed_object("x", 1.0);
        cold.ensure_connection();
        let t0 = Instant::now();
        cold.put("out", big);
        let cold_t = t0.elapsed();

        let warm = store();
        warm.seed_object("x", 1.0);
        warm.ensure_connection();
        warm.warm(8e6);
        let t1 = Instant::now();
        warm.put("out", big);
        let warm_t = t1.elapsed();
        assert!(
            warm_t < cold_t,
            "warmed {warm_t:?} should beat cold {cold_t:?}"
        );
    }
}
