//! Dynamic batching for the inference thread.
//!
//! Collect requests until either `max_batch` are in hand or `batch_window`
//! has elapsed since the first request of the batch — the standard serving
//! trade-off between latency and device utilisation.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Pull one batch from `rx`. Blocks for the first item (up to
/// `idle_timeout`); then keeps collecting until `max_batch` or
/// `batch_window` from the first item. Returns an empty vec on idle
/// timeout and `None` when the channel is closed and drained.
pub fn next_batch<T>(
    rx: &Receiver<T>,
    max_batch: usize,
    batch_window: Duration,
    idle_timeout: Duration,
) -> Option<Vec<T>> {
    debug_assert!(max_batch >= 1);
    let first = match rx.recv_timeout(idle_timeout) {
        Ok(item) => item,
        Err(RecvTimeoutError::Timeout) => return Some(Vec::new()),
        Err(RecvTimeoutError::Disconnected) => return None,
    };
    let mut batch = vec![first];
    let deadline = Instant::now() + batch_window;
    while batch.len() < max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break, // flush what we have
        }
    }
    Some(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn batches_up_to_max() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let b = next_batch(&rx, 4, Duration::from_millis(50), Duration::from_millis(50))
            .unwrap();
        assert_eq!(b, vec![0, 1, 2, 3]);
        let b2 = next_batch(&rx, 4, Duration::from_millis(50), Duration::from_millis(50))
            .unwrap();
        assert_eq!(b2, vec![4, 5, 6, 7]);
    }

    #[test]
    fn window_flushes_partial_batch() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        let t0 = Instant::now();
        let b = next_batch(&rx, 8, Duration::from_millis(30), Duration::from_secs(1))
            .unwrap();
        assert_eq!(b, vec![1]);
        assert!(t0.elapsed() >= Duration::from_millis(25));
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn idle_timeout_returns_empty() {
        let (_tx, rx) = channel::<u32>();
        let b = next_batch(&rx, 8, Duration::from_millis(10), Duration::from_millis(20))
            .unwrap();
        assert!(b.is_empty());
    }

    #[test]
    fn disconnect_returns_none_after_drain() {
        let (tx, rx) = channel();
        tx.send(42).unwrap();
        drop(tx);
        let b = next_batch(&rx, 8, Duration::from_millis(10), Duration::from_millis(20))
            .unwrap();
        assert_eq!(b, vec![42]);
        assert!(next_batch(&rx, 8, Duration::from_millis(10), Duration::from_millis(20))
            .is_none());
    }
}
