//! The serving engine: router, handler workers, dynamic batcher, and the
//! freshen thread, serving the paper's λ1 pipeline for real.
//!
//! Each request walks λ1's ops (Algorithm 1): `FrFetch(0, DataGet(model))`
//! → batched inference (native or PJRT backend, per
//! [`ServeConfig::backend`]) → `FrWarm(1, DataPut(result))`. The freshen
//! hook — run ahead of predicted bursts — prefetches the model object and
//! establishes + warms the store connection, so requests hit local data
//! and a wide congestion window.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::freshen::state::FrResult;
use crate::netsim::link::{Link, Site};
use crate::runtime::backend::BackendKind;
use crate::runtime::model::ClassifierRuntime;
use crate::serve::batcher::next_batch;
use crate::serve::fr::{Served, SharedFrState};
use crate::serve::store::LatencyStore;
use crate::util::stats::Summary;
use crate::util::time::SimDuration;

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Handler worker threads.
    pub workers: usize,
    /// Dynamic batch cap (also bounded by the largest AOT batch).
    pub max_batch: usize,
    /// How long the batcher waits to fill a batch.
    pub batch_window: Duration,
    /// Real seconds slept per simulated network second (0.001 = 1000x).
    pub time_scale: f64,
    /// Enable the freshen machinery (false = vanilla baseline).
    pub freshen: bool,
    /// TTL for prefetched model data, simulated seconds.
    pub prefetch_ttl_s: f64,
    /// Size of the model object λ1 fetches.
    pub model_bytes: f64,
    /// Size of the result λ1 writes.
    pub result_bytes: f64,
    /// Network path to the store.
    pub link: Link,
    pub seed: u64,
    /// Inference executor (native rust or PJRT).
    pub backend: BackendKind,
    /// Pad batches up to the smallest AOT size that fits (`false` runs
    /// exact batch sizes; native backend only — PJRT always pads).
    pub pad_to_aot: bool,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            workers: 4,
            max_batch: 16,
            batch_window: Duration::from_millis(2),
            time_scale: 0.001,
            freshen: true,
            prefetch_ttl_s: 10.0,
            model_bytes: 5e6,
            result_bytes: 64.0 * 1024.0,
            link: Site::Remote.link(),
            seed: 0xE2E,
            backend: BackendKind::default(),
            pad_to_aot: true,
        }
    }
}

/// Outcome of one request.
#[derive(Debug, Clone)]
pub struct RequestOutcome {
    pub logits: Vec<f32>,
    pub latency: Duration,
    pub fetch_served: Served,
    pub put_served: Served,
}

struct Request {
    row: Vec<f32>,
    respond: Sender<RequestOutcome>,
}

struct InferJob {
    row: Vec<f32>,
    reply: Sender<Vec<f32>>,
}

struct Shared {
    store: LatencyStore,
    fr: SharedFrState,
    latencies: Mutex<Vec<Duration>>,
    fetch_hits: AtomicU64,
    fetch_misses: AtomicU64,
    completed: AtomicU64,
    started: Instant,
}

/// Aggregated serving report.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub requests: u64,
    pub latency_ms: Option<Summary>,
    pub throughput_rps: f64,
    pub fetch_hit_rate: f64,
    pub store_gets: u64,
    pub store_puts: u64,
    pub wall: Duration,
}

impl ServeReport {
    pub fn print(&self, label: &str) {
        let (p50, p99, mean) = self
            .latency_ms
            .as_ref()
            .map(|s| (s.p50, s.p99, s.mean))
            .unwrap_or((0.0, 0.0, 0.0));
        println!(
            "{label:<18} requests={:<6} p50={p50:>8.2}ms p99={p99:>8.2}ms mean={mean:>8.2}ms \
             thru={:>7.1} req/s fetch-hit={:>5.1}% store-gets={}",
            self.requests,
            self.throughput_rps,
            100.0 * self.fetch_hit_rate,
            self.store_gets,
        );
    }
}

/// The engine handle.
pub struct ServeEngine {
    req_tx: Option<Sender<Request>>,
    infer_tx: Option<Sender<InferJob>>,
    workers: Vec<JoinHandle<()>>,
    infer_thread: Option<JoinHandle<()>>,
    shared: Arc<Shared>,
    input_dim: usize,
    pub config: ServeConfig,
}

impl ServeEngine {
    /// Start the engine: loads the AOT artifacts on the inference thread
    /// (PJRT state is not `Send`), spawns handler workers, seeds the store.
    pub fn start(artifacts_dir: PathBuf, config: ServeConfig) -> Result<ServeEngine> {
        let shared = Arc::new(Shared {
            store: LatencyStore::new(config.link.clone(), config.seed, config.time_scale),
            fr: SharedFrState::new(
                2,
                SimDuration::from_secs_f64(if config.freshen {
                    config.prefetch_ttl_s
                } else {
                    // Baseline: no freshen cache; every request refetches
                    // (invocation-scoped semantics).
                    0.0
                }),
                config.time_scale,
            ),
            latencies: Mutex::new(Vec::new()),
            fetch_hits: AtomicU64::new(0),
            fetch_misses: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            started: Instant::now(),
        });
        shared.store.seed_object("model", config.model_bytes);

        // Inference thread: owns all model state (PJRT state is not
        // `Send`; the native backend follows the same discipline).
        let (infer_tx, infer_rx) = channel::<InferJob>();
        let (ready_tx, ready_rx) = channel::<Result<(usize, usize)>>();
        let max_batch_cfg = config.max_batch;
        let window = config.batch_window;
        let backend = config.backend;
        let pad_to_aot = config.pad_to_aot;
        let infer_thread = std::thread::Builder::new()
            .name("inference".into())
            .spawn(move || {
                inference_loop(
                    artifacts_dir,
                    backend,
                    pad_to_aot,
                    infer_rx,
                    ready_tx,
                    max_batch_cfg,
                    window,
                )
            })
            .context("spawning inference thread")?;
        let (_max_batch, input_dim) = ready_rx
            .recv()
            .context("inference thread died before ready")??;

        // Handler workers.
        let (req_tx, req_rx) = channel::<Request>();
        let req_rx = Arc::new(Mutex::new(req_rx));
        let mut workers = Vec::new();
        for i in 0..config.workers {
            let rx = Arc::clone(&req_rx);
            let sh = Arc::clone(&shared);
            let itx = infer_tx.clone();
            let result_bytes = config.result_bytes;
            workers.push(
                std::thread::Builder::new()
                    .name(format!("handler-{i}"))
                    .spawn(move || handler_loop(rx, sh, itx, result_bytes))
                    .context("spawning handler")?,
            );
        }

        Ok(ServeEngine {
            req_tx: Some(req_tx),
            infer_tx: Some(infer_tx),
            workers,
            infer_thread: Some(infer_thread),
            shared,
            input_dim,
            config,
        })
    }

    /// Feature width of one request row (the loaded manifest's
    /// `input_dim`) — callers generating synthetic traffic should size
    /// rows with this instead of hard-coding the paper model's 3072.
    pub fn input_dim(&self) -> usize {
        self.input_dim
    }

    /// Submit one request; returns the channel the outcome arrives on.
    pub fn submit(&self, row: Vec<f32>) -> Receiver<RequestOutcome> {
        let (tx, rx) = channel();
        if let Some(q) = &self.req_tx {
            let _ = q.send(Request { row, respond: tx });
        }
        rx
    }

    /// Run the freshen hook now (prediction admitted): prefetch the model
    /// and establish+warm the store connection, concurrently with serving.
    /// Returns the join handle so callers can overlap or wait.
    pub fn freshen(&self) -> JoinHandle<()> {
        let sh = Arc::clone(&self.shared);
        let put_bytes = self.config.result_bytes;
        std::thread::spawn(move || {
            // Resource 0: prefetch the model object (Algorithm 2 lines 3-5).
            if sh.fr.freshen_claim(0) {
                let result = match sh.store.get("model") {
                    Some((version, bytes)) => FrResult::Data {
                        object_id: "model".into(),
                        version,
                        bytes,
                    },
                    None => FrResult::Failed,
                };
                sh.fr.freshen_finish(0, result);
            }
            // Resource 1: ensure + warm the put path (lines 6-8).
            if sh.fr.freshen_claim(1) {
                sh.store.ensure_connection();
                sh.store.warm((put_bytes * 4.0).max(1e6));
                sh.fr.freshen_finish(1, FrResult::Warmed);
            }
        })
    }

    /// Recycle fr_state (expired entries clear; fresh prefetches persist).
    pub fn recycle(&self) {
        self.shared.fr.recycle();
    }

    /// Aggregate report over everything served so far.
    pub fn report(&self) -> ServeReport {
        let lat = self.shared.latencies.lock().unwrap();
        let ms: Vec<f64> = lat.iter().map(|d| d.as_secs_f64() * 1e3).collect();
        let hits = self.shared.fetch_hits.load(Ordering::Relaxed);
        let misses = self.shared.fetch_misses.load(Ordering::Relaxed);
        let (gets, puts) = self.shared.store.counters();
        let wall = self.shared.started.elapsed();
        ServeReport {
            requests: self.shared.completed.load(Ordering::Relaxed),
            latency_ms: Summary::of(&ms),
            throughput_rps: lat.len() as f64 / wall.as_secs_f64().max(1e-9),
            fetch_hit_rate: if hits + misses == 0 {
                0.0
            } else {
                hits as f64 / (hits + misses) as f64
            },
            store_gets: gets,
            store_puts: puts,
            wall,
        }
    }

    /// Graceful shutdown: drain queues, join every thread.
    pub fn shutdown(mut self) -> ServeReport {
        let report_before = self.report();
        self.req_tx.take(); // close the request channel
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        self.infer_tx.take(); // now the inference channel closes
        if let Some(h) = self.infer_thread.take() {
            let _ = h.join();
        }
        report_before
    }
}

fn inference_loop(
    artifacts_dir: PathBuf,
    backend: BackendKind,
    pad_to_aot: bool,
    rx: Receiver<InferJob>,
    ready: Sender<Result<(usize, usize)>>,
    max_batch_cfg: usize,
    window: Duration,
) {
    let mut rt = match ClassifierRuntime::load_with(&artifacts_dir, backend) {
        Ok(mut rt) => {
            rt.set_pad_to_aot(pad_to_aot);
            let _ = ready.send(Ok((rt.max_batch(), rt.manifest.input_dim)));
            rt
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let max_batch = max_batch_cfg.min(rt.max_batch());
    loop {
        let Some(batch) = next_batch(&rx, max_batch, window, Duration::from_millis(50))
        else {
            return; // channel closed and drained
        };
        if batch.is_empty() {
            continue;
        }
        let rows: Vec<Vec<f32>> = batch.iter().map(|j| j.row.clone()).collect();
        match rt.infer(&rows) {
            Ok(outs) => {
                for (job, out) in batch.into_iter().zip(outs.into_iter()) {
                    let _ = job.reply.send(out);
                }
            }
            Err(e) => {
                eprintln!("inference error: {e:#}");
                // Replies drop; handlers see a closed channel and fail the
                // individual requests rather than the engine.
            }
        }
    }
}

fn handler_loop(
    rx: Arc<Mutex<Receiver<Request>>>,
    sh: Arc<Shared>,
    infer_tx: Sender<InferJob>,
    result_bytes: f64,
) {
    loop {
        let req = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        let Ok(req) = req else { return };
        let t0 = Instant::now();

        // Op 1 — FrFetch(0, DataGet(CREDS, "model")).
        let (fetch_result, fetch_served) = sh.fr.fr_fetch(0, None, || {
            match sh.store.get("model") {
                Some((version, bytes)) => FrResult::Data {
                    object_id: "model".into(),
                    version,
                    bytes,
                },
                None => FrResult::Failed,
            }
        });
        match fetch_served {
            Served::ByFreshen | Served::AfterWait => {
                sh.fetch_hits.fetch_add(1, Ordering::Relaxed)
            }
            Served::BySelf => sh.fetch_misses.fetch_add(1, Ordering::Relaxed),
        };
        let _ = fetch_result; // payload size only matters for latency

        // Op 2 — result := model(image): batched PJRT inference.
        let (reply_tx, reply_rx) = channel();
        if infer_tx
            .send(InferJob {
                row: req.row,
                reply: reply_tx,
            })
            .is_err()
        {
            return; // engine shutting down
        }
        let Ok(logits) = reply_rx.recv() else {
            continue; // inference failed for this request
        };

        // Op 3 — FrWarm(1, DataPut(CREDS, result)): the put always runs;
        // freshen buys it a live, warmed connection.
        let put_served = sh.fr.fr_warm(1, || {
            // Unfreshened path: the function establishes lazily — i.e. it
            // does nothing here and pays cold/dead costs inside put().
        });
        sh.store.put("result", result_bytes);

        let latency = t0.elapsed();
        sh.latencies.lock().unwrap().push(latency);
        sh.completed.fetch_add(1, Ordering::Relaxed);
        let _ = req.respond.send(RequestOutcome {
            logits,
            latency,
            fetch_served,
            put_served,
        });
    }
}
