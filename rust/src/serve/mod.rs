//! Real-time serving engine.
//!
//! The second substrate for the freshen runtime (the first is the
//! deterministic simulator in [`crate::platform`]): real threads, real
//! latencies, and the real PJRT-compiled classifier on the request path.
//! This is what the end-to-end example (`examples/ml_pipeline.rs`) and the
//! e2e bench run.
//!
//! Architecture (vLLM-router-style, scaled to one process):
//!
//! ```text
//!  clients ──> router (mpsc) ──> handler workers ──┐
//!                                    │ FrFetch     │ submit
//!                              [LatencyStore]      ▼
//!                                    │         dynamic batcher
//!                 freshen thread ────┘              │
//!               (prefetch + warm,           inference thread
//!                condvar FrWait)           (owns ClassifierRuntime,
//!                                            not-Send PJRT state)
//! ```
//!
//! - [`store`] — the remote datastore with netsim-derived latencies
//!   injected as real (scaled) sleeps.
//! - [`fr`] — `fr_state` shared across threads: Algorithms 4/5 with a
//!   mutex + condvar (`FrWait` is a real blocking wait here).
//! - [`batcher`] — dynamic batching: collect up to `max_batch` requests or
//!   `batch_window`, whichever first.
//! - [`engine`] — wiring, lifecycle, latency reporting.

pub mod batcher;
pub mod engine;
pub mod fr;
pub mod http;
pub mod store;

pub use engine::{ServeConfig, ServeEngine, ServeReport};
