//! `fr_state` for real threads: Algorithms 4/5 with a mutex + condvar.
//!
//! The simulator implements `FrWait` as a parked event continuation; here
//! it is a genuine blocking wait. The decision logic is the same pure
//! function ([`crate::freshen::wrappers`]); this module supplies the
//! synchronisation shell around it.

use std::sync::{Condvar, Mutex};
use std::time::Instant;

use crate::freshen::state::{Completer, FrEntry, FrResult, FrStatus};
use crate::freshen::wrappers::{fr_fetch_decision, fr_warm_decision, WrapperDecision};
use crate::util::time::{SimDuration, SimTime};

/// Shared freshen resource list for one runtime (engine process).
pub struct SharedFrState {
    entries: Mutex<Vec<FrEntry>>,
    cv: Condvar,
    epoch: Instant,
    /// Simulated-seconds per real second (matches the store's scale).
    time_scale: f64,
}

/// Which side did the work for a resource access.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Served {
    /// Result came from the freshen hook (hit).
    ByFreshen,
    /// The caller did the work itself (miss).
    BySelf,
    /// The caller waited for an in-flight freshen, then consumed it.
    AfterWait,
}

impl SharedFrState {
    pub fn new(resources: usize, ttl: SimDuration, time_scale: f64) -> SharedFrState {
        SharedFrState {
            entries: Mutex::new((0..resources).map(|_| FrEntry::new(ttl)).collect()),
            cv: Condvar::new(),
            epoch: Instant::now(),
            time_scale,
        }
    }

    fn now(&self) -> SimTime {
        let real = self.epoch.elapsed().as_secs_f64();
        SimTime((real / self.time_scale * 1e6) as u64)
    }

    /// `FrFetch(id, work)` — returns the result and who produced it.
    /// `work` runs OUTSIDE the lock (it does real network sleeps).
    pub fn fr_fetch<F>(&self, id: usize, live_version: Option<u64>, work: F) -> (FrResult, Served)
    where
        F: FnOnce() -> FrResult,
    {
        let mut waited = false;
        loop {
            let mut g = self.entries.lock().unwrap();
            match fr_fetch_decision(&mut g[id], self.now(), live_version) {
                WrapperDecision::UseResult(r) => {
                    return (
                        r,
                        if waited { Served::AfterWait } else { Served::ByFreshen },
                    )
                }
                WrapperDecision::Wait => {
                    waited = true;
                    let _g = self
                        .cv
                        .wait_while(g, |entries| entries[id].status == FrStatus::Running)
                        .unwrap();
                    // loop to re-decide
                }
                WrapperDecision::DoItYourself => {
                    drop(g); // run the real work unlocked
                    let result = work();
                    let mut g = self.entries.lock().unwrap();
                    g[id].finish(result.clone(), self.now(), Completer::Function);
                    self.cv.notify_all();
                    return (result, Served::BySelf);
                }
            }
        }
    }

    /// `FrWarm(id, work)` — same shape; `work` warms the resource.
    pub fn fr_warm<F>(&self, id: usize, work: F) -> Served
    where
        F: FnOnce(),
    {
        let mut waited = false;
        loop {
            let mut g = self.entries.lock().unwrap();
            match fr_warm_decision(&mut g[id], self.now()) {
                WrapperDecision::UseResult(_) => {
                    return if waited { Served::AfterWait } else { Served::ByFreshen }
                }
                WrapperDecision::Wait => {
                    waited = true;
                    let _g = self
                        .cv
                        .wait_while(g, |entries| entries[id].status == FrStatus::Running)
                        .unwrap();
                }
                WrapperDecision::DoItYourself => {
                    drop(g);
                    work();
                    let mut g = self.entries.lock().unwrap();
                    g[id].finish(FrResult::Warmed, self.now(), Completer::Function);
                    self.cv.notify_all();
                    return Served::BySelf;
                }
            }
        }
    }

    /// The freshen hook's side: claim resource `id` (Algorithm 2's
    /// `running` marker). Returns false when the function got there first.
    pub fn freshen_claim(&self, id: usize) -> bool {
        let mut g = self.entries.lock().unwrap();
        g[id].try_start(self.now())
    }

    /// The freshen hook's side: complete a claimed resource.
    pub fn freshen_finish(&self, id: usize, result: FrResult) {
        let mut g = self.entries.lock().unwrap();
        g[id].finish(result, self.now(), Completer::Freshen);
        self.cv.notify_all();
    }

    /// Recycle entries for the next cycle (keeps TTL-fresh data).
    pub fn recycle(&self) {
        let now = self.now();
        let mut g = self.entries.lock().unwrap();
        for e in g.iter_mut() {
            e.recycle(now);
        }
    }

    pub fn freshened_count(&self) -> usize {
        let g = self.entries.lock().unwrap();
        g.iter()
            .filter(|e| e.completed_by == Some(Completer::Freshen))
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    fn fr(n: usize) -> Arc<SharedFrState> {
        Arc::new(SharedFrState::new(n, SimDuration::from_secs(10), 1.0))
    }

    fn data(v: u64) -> FrResult {
        FrResult::Data {
            object_id: "m".into(),
            version: v,
            bytes: 1.0,
        }
    }

    #[test]
    fn function_does_work_when_no_freshen() {
        let st = fr(1);
        let (r, served) = st.fr_fetch(0, None, || data(1));
        assert_eq!(served, Served::BySelf);
        assert!(matches!(r, FrResult::Data { version: 1, .. }));
        // Second access within TTL: served from the finished entry.
        let (_, served2) = st.fr_fetch(0, None, || panic!("must not refetch"));
        assert_eq!(served2, Served::ByFreshen); // entry reuse path
    }

    #[test]
    fn freshen_first_then_function_hits() {
        let st = fr(1);
        assert!(st.freshen_claim(0));
        st.freshen_finish(0, data(7));
        let (r, served) = st.fr_fetch(0, None, || panic!("freshened"));
        assert_eq!(served, Served::ByFreshen);
        assert!(matches!(r, FrResult::Data { version: 7, .. }));
        assert_eq!(st.freshened_count(), 1);
    }

    #[test]
    fn function_waits_for_inflight_freshen() {
        let st = fr(1);
        assert!(st.freshen_claim(0));
        let st2 = Arc::clone(&st);
        // Freshen completes from another thread after 50ms.
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(50));
            st2.freshen_finish(0, data(3));
        });
        let t0 = Instant::now();
        let (r, served) = st.fr_fetch(0, None, || panic!("should wait, not redo"));
        assert_eq!(served, Served::AfterWait);
        assert!(matches!(r, FrResult::Data { version: 3, .. }));
        assert!(t0.elapsed() >= Duration::from_millis(40));
        h.join().unwrap();
    }

    #[test]
    fn late_freshen_loses_the_race() {
        let st = fr(1);
        let (_, served) = st.fr_fetch(0, None, || data(1));
        assert_eq!(served, Served::BySelf);
        // Freshen arrives late: entry is finished-and-fresh, claim fails.
        assert!(!st.freshen_claim(0));
    }

    #[test]
    fn warm_path_claims_and_waits() {
        let st = fr(2);
        assert!(st.freshen_claim(1));
        st.freshen_finish(1, FrResult::Warmed);
        assert_eq!(st.fr_warm(1, || panic!("warmed")), Served::ByFreshen);
        // Unfreshened resource: function warms it itself.
        let mut ran = false;
        assert_eq!(st.fr_warm(0, || ran = true), Served::BySelf);
        assert!(ran);
    }

    #[test]
    fn concurrent_functions_do_work_exactly_once() {
        use std::sync::atomic::{AtomicU32, Ordering};
        let st = fr(1);
        let count = Arc::new(AtomicU32::new(0));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let st = Arc::clone(&st);
            let count = Arc::clone(&count);
            handles.push(std::thread::spawn(move || {
                let (_, _) = st.fr_fetch(0, None, || {
                    count.fetch_add(1, Ordering::SeqCst);
                    std::thread::sleep(Duration::from_millis(20));
                    data(1)
                });
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(count.load(Ordering::SeqCst), 1, "work must run once");
    }
}
