//! Minimal HTTP/1.1 front end for the serving engine.
//!
//! Makes `repro serve --listen ADDR` a real service (the shape of a
//! vLLM-style router): requests come in over TCP, handlers run the λ1
//! pipeline (freshen-accelerated), and operational state is inspectable.
//!
//! Routes:
//! - `POST /classify` — body `{"image": [input_dim floats]}` (or empty
//!   for a deterministic test image). Returns logits + latency.
//! - `POST /freshen` — run the freshen hook now (returns 202).
//! - `GET /stats` — the engine's aggregate report as JSON.
//! - `GET /healthz` — liveness.
//!
//! No HTTP library exists in the offline vendor set; this is a small,
//! careful HTTP/1.1 implementation (request-line + headers +
//! content-length bodies, `Connection: close` semantics).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::serve::engine::ServeEngine;
use crate::util::json::Json;

/// A parsed HTTP request.
#[derive(Debug, Clone)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
}

/// Parse one request from a buffered stream.
pub fn parse_request<R: BufRead>(r: &mut R) -> Result<HttpRequest> {
    let mut line = String::new();
    r.read_line(&mut line).context("reading request line")?;
    let mut parts = line.split_whitespace();
    let method = parts.next().context("missing method")?.to_string();
    let path = parts.next().context("missing path")?.to_string();
    let version = parts.next().unwrap_or("HTTP/1.1");
    if !version.starts_with("HTTP/1.") {
        anyhow::bail!("unsupported version {version}");
    }
    // Headers.
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        r.read_line(&mut h).context("reading header")?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().context("bad content-length")?;
            }
        }
    }
    const MAX_BODY: usize = 4 * 1024 * 1024;
    if content_length > MAX_BODY {
        anyhow::bail!("body too large: {content_length}");
    }
    let mut body = vec![0u8; content_length];
    r.read_exact(&mut body).context("reading body")?;
    Ok(HttpRequest { method, path, body })
}

/// Serialize a response.
pub fn write_response<W: Write>(
    w: &mut W,
    status: u16,
    reason: &str,
    content_type: &str,
    body: &str,
) -> std::io::Result<()> {
    write!(
        w,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
}

fn json_response<W: Write>(w: &mut W, status: u16, body: &Json) -> std::io::Result<()> {
    let reason = match status {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        _ => "Internal Server Error",
    };
    write_response(w, status, reason, "application/json", &body.to_string())
}

/// The HTTP server wrapping a [`ServeEngine`].
pub struct HttpServer {
    engine: Arc<ServeEngine>,
    listener: TcpListener,
    stop: Arc<AtomicBool>,
}

impl HttpServer {
    /// Bind to `addr` (e.g. `"127.0.0.1:8080"`; port 0 picks a free port).
    pub fn bind(engine: Arc<ServeEngine>, addr: &str) -> Result<HttpServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        Ok(HttpServer {
            engine,
            listener,
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.listener.local_addr().expect("bound")
    }

    /// A handle that stops the accept loop (from another thread).
    pub fn stopper(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }

    /// Accept-and-serve loop; returns when the stopper fires.
    pub fn run(&self) -> Result<()> {
        self.listener
            .set_nonblocking(true)
            .context("set_nonblocking")?;
        loop {
            if self.stop.load(Ordering::Relaxed) {
                return Ok(());
            }
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    let engine = Arc::clone(&self.engine);
                    std::thread::spawn(move || {
                        let _ = handle_connection(stream, &engine);
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e).context("accept"),
            }
        }
    }
}

fn handle_connection(stream: TcpStream, engine: &ServeEngine) -> Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let req = match parse_request(&mut reader) {
        Ok(r) => r,
        Err(e) => {
            let body = Json::obj(vec![("error", Json::str(&format!("{e:#}")))]);
            json_response(&mut out, 400, &body)?;
            return Ok(());
        }
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            json_response(&mut out, 200, &Json::obj(vec![("ok", Json::Bool(true))]))?;
        }
        ("GET", "/stats") => {
            let r = engine.report();
            let lat = r.latency_ms;
            let body = Json::obj(vec![
                ("requests", Json::num(r.requests as f64)),
                (
                    "p50_ms",
                    Json::num(lat.as_ref().map(|s| s.p50).unwrap_or(0.0)),
                ),
                (
                    "p99_ms",
                    Json::num(lat.as_ref().map(|s| s.p99).unwrap_or(0.0)),
                ),
                ("throughput_rps", Json::num(r.throughput_rps)),
                ("fetch_hit_rate", Json::num(r.fetch_hit_rate)),
                ("store_gets", Json::num(r.store_gets as f64)),
                ("store_puts", Json::num(r.store_puts as f64)),
            ]);
            json_response(&mut out, 200, &body)?;
        }
        ("POST", "/freshen") => {
            // Non-blocking, like the provider calling the hook on a
            // prediction: fire and acknowledge.
            let _handle = engine.freshen();
            json_response(
                &mut out,
                202,
                &Json::obj(vec![("freshen", Json::str("started"))]),
            )?;
        }
        ("POST", "/classify") => {
            let image: Vec<f32> = if req.body.is_empty() {
                (0..engine.input_dim()).map(|j| (j % 23) as f32 / 23.0).collect()
            } else {
                let text = String::from_utf8_lossy(&req.body);
                match Json::parse(&text)
                    .ok()
                    .and_then(|j| j.get("image").and_then(Json::as_arr).map(|a| a.to_vec()))
                {
                    Some(arr) => arr.iter().filter_map(Json::as_f64).map(|v| v as f32).collect(),
                    None => {
                        json_response(
                            &mut out,
                            400,
                            &Json::obj(vec![(
                                "error",
                                Json::str("body must be {\"image\": [floats]}"),
                            )]),
                        )?;
                        return Ok(());
                    }
                }
            };
            let rx = engine.submit(image);
            match rx.recv_timeout(Duration::from_secs(60)) {
                Ok(outcome) => {
                    let body = Json::obj(vec![
                        (
                            "logits",
                            Json::arr(outcome.logits.iter().map(|&v| Json::num(v as f64))),
                        ),
                        (
                            "latency_ms",
                            Json::num(outcome.latency.as_secs_f64() * 1e3),
                        ),
                        (
                            "fetch_served_by_freshen",
                            Json::Bool(!matches!(
                                outcome.fetch_served,
                                crate::serve::fr::Served::BySelf
                            )),
                        ),
                    ]);
                    json_response(&mut out, 200, &body)?;
                }
                Err(_) => {
                    json_response(
                        &mut out,
                        500,
                        &Json::obj(vec![("error", Json::str("request timed out"))]),
                    )?;
                }
            }
        }
        _ => {
            json_response(
                &mut out,
                404,
                &Json::obj(vec![("error", Json::str("not found"))]),
            )?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_post_with_body() {
        let raw = b"POST /classify HTTP/1.1\r\nHost: x\r\nContent-Length: 5\r\n\r\nhello";
        let mut r = std::io::BufReader::new(&raw[..]);
        let req = parse_request(&mut r).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/classify");
        assert_eq!(req.body, b"hello");
    }

    #[test]
    fn parses_get_without_body() {
        let raw = b"GET /stats HTTP/1.1\r\n\r\n";
        let mut r = std::io::BufReader::new(&raw[..]);
        let req = parse_request(&mut r).unwrap();
        assert_eq!(req.method, "GET");
        assert!(req.body.is_empty());
    }

    #[test]
    fn rejects_garbage_and_oversized() {
        let raw = b"NONSENSE\r\n\r\n";
        let mut r = std::io::BufReader::new(&raw[..]);
        assert!(parse_request(&mut r).is_err());
        let big = format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n", 100 << 20);
        let mut r = std::io::BufReader::new(big.as_bytes());
        assert!(parse_request(&mut r).is_err());
    }

    #[test]
    fn response_format() {
        let mut buf = Vec::new();
        write_response(&mut buf, 200, "OK", "application/json", "{}").unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("Content-Length: 2\r\n"));
        assert!(text.ends_with("\r\n\r\n{}"));
    }
}
