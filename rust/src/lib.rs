//! # freshen-rs
//!
//! A reproduction of *"Proactive Serverless Function Resource Management"*
//! (Hunhoff et al., 2020): the **`freshen`** primitive — a hook the serverless
//! provider runs *before* a predicted function invocation so that connection
//! establishment, TCP congestion-window ramp-up, TLS handshakes and data
//! fetches happen off the critical path.
//!
//! The crate is organised as a three-layer system:
//!
//! - **L3 (this crate)** — an OpenWhisk-like serverless platform (controller,
//!   invokers, containers, language runtimes with `init`/`run`/`freshen`
//!   hooks) that runs on two substrates: a deterministic discrete-event
//!   simulator ([`simcore`]) used by every paper experiment, and a real-time
//!   threaded serving engine ([`serve`]) used by the end-to-end example.
//! - **L2 (python/compile/model.py)** — a JAX MLP image classifier (the
//!   paper's motivating λ1 function), AOT-lowered to HLO text artifacts.
//! - **L1 (python/compile/kernels/)** — Pallas fused kernels called by L2.
//!
//! The [`runtime`] module loads the AOT artifacts via the PJRT C API and
//! executes them from the rust request path; Python never runs at serve time.
//!
//! See `DESIGN.md` for the full system inventory and the per-experiment index.

pub mod util;
pub mod simcore;
pub mod netsim;
pub mod platform;
pub mod freshen;
pub mod predict;
pub mod triggers;
pub mod workload;
pub mod billing;
pub mod metrics;
pub mod obs;
pub mod nn;
pub mod runtime;
pub mod serve;
pub mod experiments;
pub mod analysis;
pub mod testkit;
pub mod cli;
