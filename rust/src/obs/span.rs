//! Lifecycle spans over sim time, and the per-world [`Tracer`] ring.
//!
//! Every span is an interval (possibly zero-length) on the simulated
//! clock, stamped with the function it concerns and the invocation (or
//! freshen-run / prediction / container) id that links it into its causal
//! tree: an invocation's `Arrival → Queue → Placement → Cold/Warm →
//! Exec → Complete` chain shares one `inv`, chain edges carry the parent
//! invocation's id next to the successor function, and freshen spans
//! carry the prediction id that admitted them. Times are integer
//! microseconds of *sim* time only — wall clocks are banned here (simlint
//! D002 deliberately does NOT allowlist `obs/`), so identical replays
//! produce identical span streams, byte for byte.
//!
//! The [`Tracer`] is a bounded ring: when full it drops the OLDEST event
//! and counts the drop, so a capped trace keeps the most recent window of
//! a run and the digest still commits to what was lost. Disabled (the
//! default) it is a single branch per call site — no allocation, no
//! recording — which is what keeps spans compiled-in without perturbing
//! legacy digests or stdout.

use std::collections::VecDeque;

use crate::platform::symbols::{FnId, Symbols};
use crate::util::time::{SimDuration, SimTime};

/// Default ring capacity per world (events kept, newest-biased).
pub const DEFAULT_SPAN_CAP: usize = 1 << 18;

/// What a span marks in an invocation's (or freshen run's) lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// Invocation submitted (`a`/`b` unused).
    Arrival,
    /// Time spent held by the dispatch queue (`dur` = wait).
    Queue,
    /// Container chosen (`a` = invoker/host id in the low bits with the
    /// placement-strategy code in the high byte — legacy's code is 0, so
    /// default-axis payloads are unchanged; `b` = memory charge MB).
    Placement,
    /// Cold start paid (`a` = container id, `b` = memory charge MB).
    ColdStart,
    /// Warm start (`a` = container id).
    WarmStart,
    /// Per-app sibling re-init — the discounted container incarnation
    /// path (`a` = container id, `b` = new memory charge MB).
    Reinit,
    /// Function body execution (`a` = freshen hits, `b` = misses).
    Exec,
    /// Invocation finished (`a` = end-to-end latency µs, `b` = 1 if the
    /// start was cold).
    Complete,
    /// Trigger-committed chain edge; `function` is the successor, `inv`
    /// the PARENT invocation (`dur` = trigger commit + service delay).
    ChainEdge,
    /// Admitted prediction (`inv` = prediction id, `dur` = lead time to
    /// the expected arrival, `a` = confidence in per-mille).
    Prediction,
    /// Completed freshen run (`inv` = prediction id or `u64::MAX` for
    /// developer-invoked runs, `a` = container id).
    FreshenRun,
    /// A prediction resolved as a miss — its freshen was wasted work
    /// (`inv` = prediction id).
    FreshenWasted,
    /// Freshen run aborted by the container-incarnation guard (`inv` =
    /// run id, `a` = container id).
    StaleAbort,
    /// Idle/TTL eviction (`inv` = container id, `a` = released MB).
    EvictionIdle,
    /// Memory-pressure eviction (`inv` = container id, `a` = released
    /// MB, `b` = 1 if it killed live warm state).
    EvictionPressure,
    /// Invocation dropped as infeasible (`a` = charge MB no host fits).
    Drop,
    /// Warm idle container demoted to the snapshotted state (`inv` =
    /// container id, `a` = warm MB before, `b` = discounted parked MB).
    SnapshotCreate,
    /// Snapshot restore began (`inv` = container id, `dur` = restore
    /// latency base + page-in, `a` = full warm MB, `b` = parked MB it
    /// resumed from).
    Restore,
}

impl SpanKind {
    pub const ALL: [SpanKind; 18] = [
        SpanKind::Arrival,
        SpanKind::Queue,
        SpanKind::Placement,
        SpanKind::ColdStart,
        SpanKind::WarmStart,
        SpanKind::Reinit,
        SpanKind::Exec,
        SpanKind::Complete,
        SpanKind::ChainEdge,
        SpanKind::Prediction,
        SpanKind::FreshenRun,
        SpanKind::FreshenWasted,
        SpanKind::StaleAbort,
        SpanKind::EvictionIdle,
        SpanKind::EvictionPressure,
        SpanKind::Drop,
        // Appended (positional codes are digest-stable): 16, 17.
        SpanKind::SnapshotCreate,
        SpanKind::Restore,
    ];

    pub fn as_str(&self) -> &'static str {
        match self {
            SpanKind::Arrival => "arrival",
            SpanKind::Queue => "queue",
            SpanKind::Placement => "placement",
            SpanKind::ColdStart => "cold_start",
            SpanKind::WarmStart => "warm_start",
            SpanKind::Reinit => "reinit",
            SpanKind::Exec => "exec",
            SpanKind::Complete => "complete",
            SpanKind::ChainEdge => "chain_edge",
            SpanKind::Prediction => "prediction",
            SpanKind::FreshenRun => "freshen_run",
            SpanKind::FreshenWasted => "freshen_wasted",
            SpanKind::StaleAbort => "stale_abort",
            SpanKind::EvictionIdle => "eviction_idle",
            SpanKind::EvictionPressure => "eviction_pressure",
            SpanKind::Drop => "drop",
            SpanKind::SnapshotCreate => "snapshot_create",
            SpanKind::Restore => "restore",
        }
    }

    pub fn parse(s: &str) -> Option<SpanKind> {
        SpanKind::ALL.iter().copied().find(|k| k.as_str() == s)
    }

    /// Stable numeric code (digest + Chrome export input).
    pub fn code(&self) -> u64 {
        SpanKind::ALL
            .iter()
            .position(|k| k == self)
            .expect("every kind is in ALL") as u64
    }
}

/// One recorded span, name-resolved at drain. `String` (not `Rc<str>`)
/// so merged span streams cross `SweepRunner`'s thread boundary (`Send`).
#[derive(Debug, Clone, PartialEq)]
pub struct SpanEvent {
    pub kind: SpanKind,
    pub function: String,
    /// Linking id: invocation, prediction, freshen-run or container id —
    /// see each [`SpanKind`]'s docs.
    pub inv: u64,
    pub start_us: u64,
    pub dur_us: u64,
    /// Kind-specific payloads (host, charge MB, confidence, ...).
    pub a: u64,
    pub b: u64,
}

/// One ring-resident span: the interned [`FnId`] only, resolved to its
/// name once at [`Tracer::drain`]. Recording therefore never allocates —
/// the hot path pays a 40-byte copy into the ring, and the per-event
/// `String` exists only for events that survive to the drain boundary.
#[derive(Debug, Clone)]
struct RawSpan {
    kind: SpanKind,
    function: FnId,
    inv: u64,
    start_us: u64,
    dur_us: u64,
    a: u64,
    b: u64,
}

/// Bounded, deterministic span recorder carried by each `World`.
///
/// Two events can fail to reach the drain, and they are NOT the same
/// thing: a **dropped** event matched the filter but fell out of the
/// full ring (data loss — the digest commits to it), while a
/// **filtered** event was excluded on purpose by the name filter (not
/// loss; the stream never contained it). They were historically
/// conflated by omission — filter misses vanished without any count —
/// so a capped, filtered trace could not tell "my cap is too small"
/// from "my filter is too narrow". The split counters answer that.
#[derive(Debug, Clone, Default)]
pub struct Tracer {
    enabled: bool,
    cap: usize,
    filter: Option<String>,
    buf: VecDeque<RawSpan>,
    dropped: u64,
    filtered: u64,
}

impl Tracer {
    /// The default: recording off, every call site a single branch.
    pub fn disabled() -> Tracer {
        Tracer::default()
    }

    /// Recording on, keeping at most `cap` events (oldest dropped first).
    /// `filter` keeps only spans whose function name contains it (shared
    /// pools qualify names as `app/function`, so an app name matches its
    /// whole tenant).
    pub fn enabled(cap: usize, filter: Option<String>) -> Tracer {
        Tracer {
            enabled: true,
            cap: cap.max(1),
            filter,
            buf: VecDeque::new(),
            dropped: 0,
            filtered: 0,
        }
    }

    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Record one span. A single branch when disabled; call sites pass
    /// the interned [`FnId`] they already hold, so recording never
    /// hashes or allocates a name — `syms` is consulted only when a
    /// name filter is installed (resolve is an index into the intern
    /// table).
    #[inline]
    #[allow(clippy::too_many_arguments)]
    pub fn record(
        &mut self,
        syms: &Symbols,
        kind: SpanKind,
        function: FnId,
        inv: u64,
        start: SimTime,
        dur: SimDuration,
        a: u64,
        b: u64,
    ) {
        if !self.enabled {
            return;
        }
        if let Some(f) = &self.filter {
            if !syms.resolve(function).contains(f.as_str()) {
                // Deliberate exclusion, not ring loss: counted apart from
                // `dropped` (see type docs).
                self.filtered += 1;
                return;
            }
        }
        if self.buf.len() >= self.cap {
            self.buf.pop_front();
            self.dropped += 1;
        }
        self.buf.push_back(RawSpan {
            kind,
            function,
            inv,
            start_us: start.micros(),
            dur_us: dur.micros(),
            a,
            b,
        });
    }

    /// Take the recorded events (in record order, names resolved through
    /// `syms`) and the drop count, leaving the tracer empty but still
    /// enabled. This is the one place a span's function name becomes an
    /// owned `String` — the merge/export boundary.
    pub fn drain(&mut self, syms: &Symbols) -> (Vec<SpanEvent>, u64) {
        let events = std::mem::take(&mut self.buf)
            .into_iter()
            .map(|r| SpanEvent {
                kind: r.kind,
                function: syms.resolve(r.function).to_string(),
                inv: r.inv,
                start_us: r.start_us,
                dur_us: r.dur_us,
                a: r.a,
                b: r.b,
            })
            .collect();
        let dropped = std::mem::take(&mut self.dropped);
        (events, dropped)
    }

    /// Events excluded by the name filter so far (see type docs). Not
    /// reset by [`Tracer::drain`] — take it separately.
    pub fn filtered(&self) -> u64 {
        self.filtered
    }

    /// Take (and reset) the filter-exclusion count — the drain-time
    /// companion to the `(events, dropped)` pair.
    pub fn take_filtered(&mut self) -> u64 {
        std::mem::take(&mut self.filtered)
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

/// Merged span streams, grouped by replay world — the app name in
/// per-app pool mode, a `pool-<seed>` key per shard in shared mode —
/// and kept in **sorted group order** at all times. Because each group
/// is produced whole by exactly one world and the groups are re-sorted
/// on every merge, the merged value is a canonical function of the set
/// of worlds replayed: any partition of the apps across shards and any
/// merge order yields the same bytes (the [`MacroMetrics`]
/// shard-invariance contract, extended to ordered streams).
///
/// [`MacroMetrics`]: crate::workload::macrotrace::replay::MacroMetrics
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SpanSink {
    /// `(group key, events in record order)`, sorted by key.
    groups: Vec<(String, Vec<SpanEvent>)>,
    /// Ring-capacity drops summed across constituent worlds.
    pub dropped: u64,
    /// Name-filter exclusions summed across constituent worlds. Kept
    /// OUT of [`SpanSink::digest`]: the digest commits to the stream and
    /// its losses, and a filtered event was never part of the stream —
    /// folding it in would retroactively change every filtered run's
    /// span digest without changing a single recorded byte.
    pub filtered: u64,
}

impl SpanSink {
    /// Add one world's drained stream under `key`, keeping sort order.
    /// Empty streams are skipped so sparse traces stay small (emptiness
    /// is a deterministic property of the world, so skipping cannot
    /// differ between partitions).
    pub fn push_group(&mut self, key: String, events: Vec<SpanEvent>, dropped: u64) {
        self.dropped += dropped;
        if events.is_empty() {
            return;
        }
        match self.groups.binary_search_by(|(k, _)| k.as_str().cmp(&key)) {
            // A group key is produced by exactly one world; a duplicate
            // means the same world was pushed twice — append in key
            // order so even that stays deterministic.
            Ok(i) => self.groups[i].1.extend(events),
            Err(i) => self.groups.insert(i, (key, events)),
        }
    }

    /// Commutative merge (key-sorted union; see type docs).
    pub fn merge(&mut self, other: &SpanSink) {
        self.dropped += other.dropped;
        self.filtered += other.filtered;
        for (k, evs) in &other.groups {
            match self.groups.binary_search_by(|(g, _)| g.as_str().cmp(k)) {
                Ok(i) => self.groups[i].1.extend(evs.iter().cloned()),
                Err(i) => self.groups.insert(i, (k.clone(), evs.clone())),
            }
        }
    }

    pub fn groups(&self) -> &[(String, Vec<SpanEvent>)] {
        &self.groups
    }

    /// Total recorded events across groups.
    pub fn len(&self) -> usize {
        self.groups.iter().map(|(_, e)| e.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.groups.is_empty()
    }

    /// Stable u64 fingerprint of the merged stream: folds every event of
    /// every group, in canonical (sorted-group, record) order, plus the
    /// drop count. Same fold idiom as `LatencyHist::digest`.
    pub fn digest(&self) -> u64 {
        const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;
        let mut h = self.len() as u64;
        let mut fold = |v: u64| {
            h = (h.rotate_left(5) ^ v).wrapping_mul(SEED);
        };
        for (key, events) in &self.groups {
            fold(str_hash(key));
            for e in events {
                fold(e.kind.code());
                fold(str_hash(&e.function));
                fold(e.inv);
                fold(e.start_us);
                fold(e.dur_us);
                fold(e.a);
                fold(e.b);
            }
        }
        fold(self.dropped);
        h
    }
}

/// FxHash of a string (the same stable identity `app_hash` uses).
pub(crate) fn str_hash(s: &str) -> u64 {
    use std::hash::Hasher;
    let mut h = crate::util::fxhash::FxHasher::default();
    h.write(s.as_bytes());
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(tr: &mut Tracer, syms: &mut Symbols, kind: SpanKind, f: &str, t: u64) {
        let fid = syms.intern(f);
        tr.record(syms, kind, fid, 1, SimTime(t), SimDuration(10), 0, 0);
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let mut syms = Symbols::new();
        let mut tr = Tracer::disabled();
        ev(&mut tr, &mut syms, SpanKind::Arrival, "f", 5);
        assert!(tr.is_empty());
        assert!(!tr.is_enabled());
        let (events, dropped) = tr.drain(&syms);
        assert!(events.is_empty());
        assert_eq!(dropped, 0);
    }

    #[test]
    fn ring_drops_oldest_and_counts() {
        let mut syms = Symbols::new();
        let mut tr = Tracer::enabled(2, None);
        ev(&mut tr, &mut syms, SpanKind::Arrival, "a", 1);
        ev(&mut tr, &mut syms, SpanKind::Arrival, "b", 2);
        ev(&mut tr, &mut syms, SpanKind::Arrival, "c", 3);
        let (events, dropped) = tr.drain(&syms);
        assert_eq!(dropped, 1);
        assert_eq!(
            events.iter().map(|e| e.function.as_str()).collect::<Vec<_>>(),
            vec!["b", "c"]
        );
        // Drained but still enabled: keeps recording.
        ev(&mut tr, &mut syms, SpanKind::Exec, "d", 4);
        assert_eq!(tr.len(), 1);
    }

    #[test]
    fn filter_keeps_matching_functions_only() {
        let mut syms = Symbols::new();
        let mut tr = Tracer::enabled(16, Some("app-1/".to_string()));
        ev(&mut tr, &mut syms, SpanKind::Arrival, "app-1/run", 1);
        ev(&mut tr, &mut syms, SpanKind::Arrival, "app-2/run", 2);
        // The exclusion counts as filtered, NOT as a ring drop.
        assert_eq!(tr.filtered(), 1);
        let (events, dropped) = tr.drain(&syms);
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].function, "app-1/run");
        assert_eq!(dropped, 0);
        assert_eq!(tr.take_filtered(), 1);
        assert_eq!(tr.filtered(), 0, "take resets the count");
    }

    /// The two loss-adjacent counters stay independent: ring overflow
    /// counts in `dropped` only, filter misses in `filtered` only, and a
    /// trace exercising both reports both exactly.
    #[test]
    fn filtered_and_dropped_are_split_counters() {
        let mut syms = Symbols::new();
        let mut tr = Tracer::enabled(2, Some("keep".to_string()));
        for t in 0..3 {
            ev(&mut tr, &mut syms, SpanKind::Exec, "keep/f", t);
        }
        for t in 0..5 {
            ev(&mut tr, &mut syms, SpanKind::Exec, "other/g", t);
        }
        assert_eq!(tr.filtered(), 5);
        let (events, dropped) = tr.drain(&syms);
        assert_eq!(events.len(), 2);
        assert_eq!(dropped, 1, "only the ring overflow is a drop");
        assert_eq!(tr.take_filtered(), 5);
        // An unfiltered tracer never counts filtered, even at cap.
        let mut tr = Tracer::enabled(1, None);
        ev(&mut tr, &mut syms, SpanKind::Exec, "a", 1);
        ev(&mut tr, &mut syms, SpanKind::Exec, "b", 2);
        let (_, dropped) = tr.drain(&syms);
        assert_eq!(dropped, 1);
        assert_eq!(tr.filtered(), 0);
    }

    #[test]
    fn kind_codes_and_names_are_stable_and_distinct() {
        let mut seen = std::collections::HashSet::new();
        for k in SpanKind::ALL {
            assert!(seen.insert(k.as_str()), "duplicate name {k:?}");
            assert_eq!(SpanKind::parse(k.as_str()), Some(k));
            assert_eq!(SpanKind::ALL[k.code() as usize], k);
        }
        assert_eq!(SpanKind::parse("bogus"), None);
    }

    #[test]
    fn sink_merge_is_partition_invariant() {
        let mk = |f: &str, t: u64| SpanEvent {
            kind: SpanKind::Exec,
            function: f.to_string(),
            inv: 0,
            start_us: t,
            dur_us: 1,
            a: 0,
            b: 0,
        };
        let groups = [
            ("app-a", vec![mk("f1", 1), mk("f1", 9)]),
            ("app-b", vec![mk("g", 4)]),
            ("app-c", vec![mk("h", 2)]),
        ];
        // Serial: all groups into one sink in sorted order.
        let mut serial = SpanSink::default();
        for (k, evs) in &groups {
            serial.push_group(k.to_string(), evs.clone(), 0);
        }
        // Sharded: {a,c} on one shard, {b} on another, merged b-first.
        let (mut s1, mut s2) = (SpanSink::default(), SpanSink::default());
        s1.push_group("app-a".into(), groups[0].1.clone(), 0);
        s1.push_group("app-c".into(), groups[2].1.clone(), 0);
        s2.push_group("app-b".into(), groups[1].1.clone(), 0);
        let mut merged = SpanSink::default();
        merged.merge(&s2);
        merged.merge(&s1);
        assert_eq!(merged, serial);
        assert_eq!(merged.digest(), serial.digest());
        assert_eq!(merged.len(), 4);
    }

    #[test]
    fn sink_digest_sees_content_and_drops() {
        let mk = |t: u64| SpanEvent {
            kind: SpanKind::Queue,
            function: "f".to_string(),
            inv: 7,
            start_us: t,
            dur_us: 3,
            a: 0,
            b: 0,
        };
        let mut a = SpanSink::default();
        a.push_group("g".into(), vec![mk(1)], 0);
        let mut b = SpanSink::default();
        b.push_group("g".into(), vec![mk(2)], 0);
        assert_ne!(a.digest(), b.digest());
        let mut c = SpanSink::default();
        c.push_group("g".into(), vec![mk(1)], 5);
        assert_ne!(a.digest(), c.digest());
        // Empty groups are skipped entirely.
        let mut d = SpanSink::default();
        d.push_group("empty".into(), Vec::new(), 0);
        assert!(d.is_empty());
    }
}
