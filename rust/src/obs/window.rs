//! Rolling per-function telemetry windows — the feed the ROADMAP's
//! closed-loop adaptive controller will consume.
//!
//! Everything here is integer-only and mergeable: counts, a power-of-two
//! latency histogram, and summed absolute prediction error, accumulated
//! per function over fixed-width sim-time windows. Merging two
//! [`WindowSet`]s (across shards, seeds, or days) sums counters bin-wise
//! and takes maxes for per-window peaks, so the merged value is
//! independent of partition and merge order — the same contract as
//! `MacroMetrics`. No floats live in these structs (simlint D003 covers
//! `obs/`); rates like cold-start fraction are derived at print time.

use crate::util::fxhash::FxHashMap;

/// Default window width: 5 simulated minutes.
pub const DEFAULT_WINDOW_US: u64 = 300_000_000;

/// Power-of-two-bucketed histogram of microsecond durations. Bin 0 holds
/// zero; bin `b ≥ 1` holds `[2^(b-1), 2^b)` µs; bin 31 absorbs the tail
/// (≥ 2^30 µs ≈ 18 sim-minutes). Bin-wise summable.
#[derive(Debug, Clone, PartialEq)]
pub struct Pow2Hist {
    bins: [u64; 32],
    pub count: u64,
}

impl Default for Pow2Hist {
    fn default() -> Pow2Hist {
        Pow2Hist { bins: [0; 32], count: 0 }
    }
}

impl Pow2Hist {
    #[inline]
    pub fn record_us(&mut self, us: u64) {
        let bin = (64 - us.leading_zeros() as usize).min(31);
        self.bins[bin] += 1;
        self.count += 1;
    }

    pub fn merge(&mut self, other: &Pow2Hist) {
        for (b, v) in self.bins.iter_mut().zip(other.bins.iter()) {
            *b += v;
        }
        self.count += other.count;
    }

    /// Lower bound (µs) of the bucket holding the `pct`-th percentile
    /// (0..=100), or 0 for an empty histogram.
    pub fn quantile_us(&self, pct: u64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        // Rank of the requested percentile, 1-based, rounded up.
        let rank = (self.count * pct.min(100)).div_ceil(100).max(1);
        let mut seen = 0u64;
        for (bin, v) in self.bins.iter().enumerate() {
            seen += v;
            if seen >= rank {
                return if bin == 0 { 0 } else { 1u64 << (bin - 1) };
            }
        }
        1u64 << 30
    }

    fn fold_into(&self, fold: &mut impl FnMut(u64)) {
        fold(self.count);
        for &b in &self.bins {
            fold(b);
        }
    }
}

/// Accumulated telemetry for one function, plus per-window peaks folded
/// over fixed-width sim-time windows.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FnWindow {
    pub invocations: u64,
    pub cold_starts: u64,
    /// Invocations that waited in the dispatch queue.
    pub queued: u64,
    pub queue_wait: Pow2Hist,
    /// Summed |observed arrival − predicted arrival| µs (IAT drift vs
    /// the predictor), over `iat_samples` matched arrivals.
    pub iat_abs_err_us: u64,
    pub iat_samples: u64,
    /// Predictions that expired unmatched — their freshen was wasted.
    pub wasted_freshens: u64,
    /// Freshen runs aborted by the container-incarnation guard.
    pub stale_aborts: u64,
    /// Invocations served by restoring a snapshotted container (neither
    /// a cold start nor a warm hit). Zero unless the snapshot axis is on.
    pub restored: u64,
    /// This function's containers demoted warm → snapshotted.
    pub snapshots: u64,
    /// Distinct windows in which this function completed work.
    pub windows: u64,
    pub peak_window_invocations: u64,
    pub peak_window_cold: u64,
    cur_window: u64,
    cur_inv: u64,
    cur_cold: u64,
    open: bool,
}

impl FnWindow {
    fn roll(&mut self, window_idx: u64) {
        if !self.open {
            self.open = true;
            self.cur_window = window_idx;
        } else if window_idx != self.cur_window {
            self.close_window();
            self.open = true;
            self.cur_window = window_idx;
        }
    }

    fn close_window(&mut self) {
        if !self.open {
            return;
        }
        self.windows += 1;
        self.peak_window_invocations = self.peak_window_invocations.max(self.cur_inv);
        self.peak_window_cold = self.peak_window_cold.max(self.cur_cold);
        self.cur_inv = 0;
        self.cur_cold = 0;
        self.open = false;
    }

    fn merge(&mut self, other: &FnWindow) {
        debug_assert!(!self.open && !other.open, "merge requires finalized windows");
        self.invocations += other.invocations;
        self.cold_starts += other.cold_starts;
        self.queued += other.queued;
        self.queue_wait.merge(&other.queue_wait);
        self.iat_abs_err_us += other.iat_abs_err_us;
        self.iat_samples += other.iat_samples;
        self.wasted_freshens += other.wasted_freshens;
        self.stale_aborts += other.stale_aborts;
        self.restored += other.restored;
        self.snapshots += other.snapshots;
        self.windows += other.windows;
        self.peak_window_invocations =
            self.peak_window_invocations.max(other.peak_window_invocations);
        self.peak_window_cold = self.peak_window_cold.max(other.peak_window_cold);
    }

    /// Cold-start fraction in per-mille (integer-only surface).
    pub fn cold_per_mille(&self) -> u64 {
        if self.invocations == 0 {
            0
        } else {
            self.cold_starts * 1000 / self.invocations
        }
    }

    /// Mean |arrival − prediction| in µs.
    pub fn iat_drift_us(&self) -> u64 {
        if self.iat_samples == 0 {
            0
        } else {
            self.iat_abs_err_us / self.iat_samples
        }
    }
}

/// Per-function rolling windows for one world / one merged replay.
/// Disabled by default (one bool test per call site); opt in via
/// `--fn-windows`. Keys are function names — unique per world in per-app
/// pool mode, qualified `app/function` in shared pools — so merged maps
/// never alias across tenants.
#[derive(Debug, Clone, PartialEq)]
pub struct WindowSet {
    pub enabled: bool,
    pub window_us: u64,
    map: FxHashMap<String, FnWindow>,
    /// Latest unexpired predicted-arrival µs per function, matched (and
    /// consumed) by the next observed arrival.
    pending_pred: FxHashMap<String, u64>,
}

impl Default for WindowSet {
    fn default() -> WindowSet {
        WindowSet {
            enabled: false,
            window_us: DEFAULT_WINDOW_US,
            map: FxHashMap::default(),
            pending_pred: FxHashMap::default(),
        }
    }
}

impl WindowSet {
    fn entry(&mut self, function: &str) -> &mut FnWindow {
        if !self.map.contains_key(function) {
            self.map.insert(function.to_string(), FnWindow::default());
        }
        self.map.get_mut(function).expect("just inserted")
    }

    pub fn on_arrival(&mut self, function: &str, now_us: u64) {
        if let Some(expected) = self.pending_pred.remove(function) {
            let w = self.entry(function);
            w.iat_samples += 1;
            w.iat_abs_err_us += now_us.abs_diff(expected);
        }
    }

    pub fn note_prediction(&mut self, function: &str, expected_at_us: u64) {
        self.pending_pred.insert(function.to_string(), expected_at_us);
    }

    pub fn on_queue_wait(&mut self, function: &str, waited_us: u64) {
        let w = self.entry(function);
        w.queued += 1;
        w.queue_wait.record_us(waited_us);
    }

    pub fn on_complete(&mut self, function: &str, cold: bool, at_us: u64) {
        let idx = at_us / self.window_us.max(1);
        let w = self.entry(function);
        w.roll(idx);
        w.invocations += 1;
        w.cur_inv += 1;
        if cold {
            w.cold_starts += 1;
            w.cur_cold += 1;
        }
    }

    pub fn on_wasted_freshen(&mut self, function: &str) {
        self.entry(function).wasted_freshens += 1;
    }

    pub fn on_stale_abort(&mut self, function: &str) {
        self.entry(function).stale_aborts += 1;
    }

    pub fn on_restore(&mut self, function: &str) {
        self.entry(function).restored += 1;
    }

    pub fn on_snapshot(&mut self, function: &str) {
        self.entry(function).snapshots += 1;
    }

    /// Close every open window and take the accumulated set, leaving
    /// this one empty (still enabled). Unmatched predictions are
    /// discarded — they are counted as wasted when they expire, not
    /// here.
    pub fn take_finalized(&mut self) -> WindowSet {
        let mut map = std::mem::take(&mut self.map);
        self.pending_pred.clear();
        for w in map.values_mut() {
            w.close_window();
        }
        WindowSet { enabled: true, window_us: self.window_us, map, pending_pred: FxHashMap::default() }
    }

    /// Commutative merge of finalized sets (sums; maxes for peaks).
    pub fn merge(&mut self, other: &WindowSet) {
        self.enabled |= other.enabled;
        for (k, w) in &other.map {
            if let Some(mine) = self.map.get_mut(k) {
                mine.merge(w);
            } else {
                self.map.insert(k.clone(), w.clone());
            }
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn get(&self, function: &str) -> Option<&FnWindow> {
        self.map.get(function)
    }

    /// Rows sorted by invocations desc, name asc — the display order.
    pub fn top_by_invocations(&self, n: usize) -> Vec<(&str, &FnWindow)> {
        let mut rows: Vec<(&str, &FnWindow)> =
            self.map.iter().map(|(k, v)| (k.as_str(), v)).collect();
        rows.sort_by(|a, b| b.1.invocations.cmp(&a.1.invocations).then(a.0.cmp(b.0)));
        rows.truncate(n);
        rows
    }

    /// Stable u64 fingerprint over name-sorted rows (same fold idiom as
    /// `LatencyHist::digest`).
    pub fn digest(&self) -> u64 {
        const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;
        let mut h = self.map.len() as u64;
        let mut fold = |v: u64| {
            h = (h.rotate_left(5) ^ v).wrapping_mul(SEED);
        };
        let mut names: Vec<&String> = self.map.keys().collect();
        names.sort();
        for name in names {
            let w = &self.map[name];
            fold(super::span::str_hash(name));
            fold(w.invocations);
            fold(w.cold_starts);
            fold(w.queued);
            w.queue_wait.fold_into(&mut fold);
            fold(w.iat_abs_err_us);
            fold(w.iat_samples);
            fold(w.wasted_freshens);
            fold(w.stale_aborts);
            // Snapshot-axis counters fold only when touched, so every
            // legacy (axis-off) window digest is bit-identical to the
            // fold that predated these fields.
            if w.restored != 0 || w.snapshots != 0 {
                fold(w.restored);
                fold(w.snapshots);
            }
            fold(w.windows);
            fold(w.peak_window_invocations);
            fold(w.peak_window_cold);
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pow2_hist_bins_and_quantiles() {
        let mut h = Pow2Hist::default();
        h.record_us(0);
        assert_eq!(h.quantile_us(50), 0);
        for us in [1, 2, 3, 1000, 1000, 1_000_000] {
            h.record_us(us);
        }
        assert_eq!(h.count, 7);
        // p100 lands in the bucket holding 1_000_000 ([2^19, 2^20)).
        assert_eq!(h.quantile_us(100), 1 << 19);
        // Median lands in the 1000 µs bucket region or below.
        assert!(h.quantile_us(50) <= 512);
        // Tail bin absorbs huge values.
        let mut t = Pow2Hist::default();
        t.record_us(u64::MAX);
        assert_eq!(t.quantile_us(100), 1 << 30);
    }

    #[test]
    fn windows_roll_and_peaks_fold() {
        let mut ws = WindowSet { enabled: true, window_us: 100, ..WindowSet::default() };
        // Window 0: three completions, one cold.
        ws.on_complete("f", true, 10);
        ws.on_complete("f", false, 20);
        ws.on_complete("f", false, 99);
        // Window 2: one completion.
        ws.on_complete("f", false, 250);
        let done = ws.take_finalized();
        assert!(ws.is_empty(), "take leaves the live set empty");
        let w = done.get("f").expect("f tracked");
        assert_eq!(w.invocations, 4);
        assert_eq!(w.cold_starts, 1);
        assert_eq!(w.windows, 2);
        assert_eq!(w.peak_window_invocations, 3);
        assert_eq!(w.peak_window_cold, 1);
        assert_eq!(w.cold_per_mille(), 250);
    }

    #[test]
    fn prediction_drift_matches_next_arrival_once() {
        let mut ws = WindowSet { enabled: true, ..WindowSet::default() };
        ws.note_prediction("f", 1_000);
        ws.on_arrival("f", 1_300);
        ws.on_arrival("f", 9_999); // no pending prediction: not a sample
        ws.note_prediction("g", 5_000);
        ws.on_arrival("g", 4_000); // early arrivals count too
        let done = ws.take_finalized();
        let f = done.get("f").unwrap();
        assert_eq!((f.iat_samples, f.iat_abs_err_us), (1, 300));
        assert_eq!(f.iat_drift_us(), 300);
        let g = done.get("g").unwrap();
        assert_eq!((g.iat_samples, g.iat_abs_err_us), (1, 1_000));
    }

    #[test]
    fn merge_is_partition_invariant() {
        let run = |names: &[&str]| {
            let mut ws = WindowSet { enabled: true, window_us: 100, ..WindowSet::default() };
            for (i, f) in names.iter().enumerate() {
                ws.on_complete(f, i % 2 == 0, (i as u64) * 60);
                ws.on_queue_wait(f, 10 + i as u64);
                ws.on_stale_abort(f);
            }
            ws.take_finalized()
        };
        let serial = run(&["a", "b", "a", "c"]);
        // "Sharded": a+c in one world, b in another, merged b-first.
        let mut merged = run(&["b"]);
        merged.merge(&run(&["a", "a", "c"]));
        // Counter totals agree regardless of partition.
        for f in ["a", "b", "c"] {
            let (s, m) = (serial.get(f).unwrap(), merged.get(f).unwrap());
            assert_eq!(s.invocations, m.invocations, "{f}");
            assert_eq!(s.queued, m.queued, "{f}");
            assert_eq!(s.stale_aborts, m.stale_aborts, "{f}");
        }
        assert_eq!(serial.len(), merged.len());
    }

    #[test]
    fn snapshot_counters_merge_and_gate_the_digest() {
        let mut ws = WindowSet { enabled: true, ..WindowSet::default() };
        ws.on_complete("f", false, 0);
        let plain = ws.take_finalized();
        let mut ws = WindowSet { enabled: true, ..WindowSet::default() };
        ws.on_complete("f", false, 0);
        ws.on_restore("f");
        ws.on_snapshot("f");
        ws.on_snapshot("f");
        let snap = ws.take_finalized();
        let w = snap.get("f").unwrap();
        assert_eq!((w.restored, w.snapshots), (1, 2));
        // Untouched counters leave the digest exactly as before the
        // fields existed; touched ones change it.
        assert_ne!(plain.digest(), snap.digest());
        let mut merged = plain.clone();
        merged.merge(&snap);
        let m = merged.get("f").unwrap();
        assert_eq!((m.invocations, m.restored, m.snapshots), (2, 1, 2));
    }

    #[test]
    fn top_rows_sorted_and_digest_stable() {
        let mut ws = WindowSet { enabled: true, ..WindowSet::default() };
        for _ in 0..3 {
            ws.on_complete("hot", false, 0);
        }
        ws.on_complete("cold", true, 0);
        let done = ws.take_finalized();
        let rows = done.top_by_invocations(10);
        assert_eq!(rows[0].0, "hot");
        assert_eq!(rows[1].0, "cold");
        assert_eq!(done.top_by_invocations(1).len(), 1);
        assert_eq!(done.digest(), done.clone().digest());
        assert_ne!(done.digest(), WindowSet::default().digest());
    }
}
