//! Deterministic, sim-time-only observability: lifecycle spans, span
//! exporters, and rolling per-function telemetry windows.
//!
//! Three pieces, all compiled in and all off by default so legacy
//! digests and stdout stay byte-identical:
//!
//! - [`span`]: every invocation's causally-linked span tree (arrival →
//!   queue → placement → cold/warm/re-init → exec → complete, plus
//!   predictions, freshen runs, evictions, chain edges) recorded into a
//!   bounded per-world [`Tracer`] ring and merged across shards by
//!   [`SpanSink`] with the same any-`--shards × --parallel`
//!   byte-identical contract as `MacroMetrics`.
//! - [`export`]: JSONL and Chrome/Perfetto `trace_event` renderings
//!   (`--span-log` / `--span-format`) plus the `repro spans` summarizer.
//! - [`window`]: integer-only, mergeable per-function windows (cold
//!   rate, queue-wait histogram, IAT drift vs the predictor, wasted and
//!   stale freshens) — the feed for the ROADMAP's adaptive controller.
//!
//! This module is deliberately **inside** the simlint determinism
//! perimeter: `obs/` is in the D001/D003 path sets and NOT in the D002
//! wall-clock allowlist. Observability reads the simulated clock only.

pub mod export;
pub mod span;
pub mod window;

pub use export::{summarize, to_chrome, to_jsonl, SpanFormat};
pub use span::{SpanEvent, SpanKind, SpanSink, Tracer, DEFAULT_SPAN_CAP};
pub use window::{FnWindow, Pow2Hist, WindowSet};
