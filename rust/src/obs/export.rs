//! Span-stream exporters and the `repro spans` summarizer.
//!
//! Two formats, both built on `util::json` (no serde in the offline
//! vendor set):
//!
//! - **JSONL**: one object per span, in canonical (row, group, record)
//!   order — greppable, diffable, and byte-stable across reruns.
//! - **Chrome `trace_event` JSON**: a `{"traceEvents": [...]}` object of
//!   `ph:"X"` complete events loadable in Perfetto / `chrome://tracing`.
//!   `pid` is the row (experiment cell) index, `tid` indexes the
//!   function within its row (name-sorted), and events are globally
//!   sorted by timestamp so `ts` is monotone non-decreasing.
//!
//! Timestamps are sim-time microseconds straight off the spans — the
//! `trace_event` µs unit, no conversion.

use std::collections::BTreeMap;

use super::span::{SpanEvent, SpanKind, SpanSink};
use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanFormat {
    Jsonl,
    Chrome,
}

impl SpanFormat {
    pub fn parse(s: &str) -> Option<SpanFormat> {
        match s {
            "jsonl" => Some(SpanFormat::Jsonl),
            "chrome" => Some(SpanFormat::Chrome),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            SpanFormat::Jsonl => "jsonl",
            SpanFormat::Chrome => "chrome",
        }
    }
}

/// Render `rows` — one `(label, sink)` per experiment cell — in `format`.
pub fn export(rows: &[(String, &SpanSink)], format: SpanFormat) -> String {
    match format {
        SpanFormat::Jsonl => to_jsonl(rows),
        SpanFormat::Chrome => to_chrome(rows),
    }
}

/// One JSON object per line per span, canonical order, trailing newline.
pub fn to_jsonl(rows: &[(String, &SpanSink)]) -> String {
    let mut out = String::new();
    for (cell, sink) in rows {
        for (group, events) in sink.groups() {
            for e in events {
                let line = Json::obj(vec![
                    ("cell", Json::str(cell)),
                    ("group", Json::str(group)),
                    ("kind", Json::str(e.kind.as_str())),
                    ("fn", Json::str(&e.function)),
                    ("inv", Json::num(e.inv as f64)),
                    ("ts", Json::num(e.start_us as f64)),
                    ("dur", Json::num(e.dur_us as f64)),
                    ("a", Json::num(e.a as f64)),
                    ("b", Json::num(e.b as f64)),
                ]);
                out.push_str(&line.to_string());
                out.push('\n');
            }
        }
    }
    out
}

/// Chrome/Perfetto `trace_event` JSON: `ph:"X"` complete events sorted
/// by `(ts, pid, tid, ...)`, preceded by `ph:"M"` process/thread name
/// metadata so rows read as cells and tracks as functions.
pub fn to_chrome(rows: &[(String, &SpanSink)]) -> String {
    let mut events: Vec<Json> = Vec::new();
    // (sort key, event) for the timed slices; metadata goes first as-is.
    let mut slices: Vec<((u64, usize, u64, u64, u64), Json)> = Vec::new();
    for (pid, (cell, sink)) in rows.iter().enumerate() {
        // Name-sorted function → tid within this row.
        let mut tids: BTreeMap<&str, u64> = BTreeMap::new();
        for (_, evs) in sink.groups() {
            for e in evs {
                let next = tids.len() as u64;
                tids.entry(e.function.as_str()).or_insert(next);
            }
        }
        // BTreeMap iteration is name-sorted but insertion above was
        // record-ordered; renumber in sorted order for stable tids.
        for (i, (_, tid)) in tids.iter_mut().enumerate() {
            *tid = i as u64;
        }
        events.push(Json::obj(vec![
            ("name", Json::str("process_name")),
            ("ph", Json::str("M")),
            ("pid", Json::num(pid as f64)),
            ("args", Json::obj(vec![("name", Json::str(cell))])),
        ]));
        for (name, tid) in &tids {
            events.push(Json::obj(vec![
                ("name", Json::str("thread_name")),
                ("ph", Json::str("M")),
                ("pid", Json::num(pid as f64)),
                ("tid", Json::num(*tid as f64)),
                ("args", Json::obj(vec![("name", Json::str(name))])),
            ]));
        }
        for (group, evs) in sink.groups() {
            for e in evs {
                let tid = tids[e.function.as_str()];
                let slice = Json::obj(vec![
                    ("name", Json::str(e.kind.as_str())),
                    ("cat", Json::str(group)),
                    ("ph", Json::str("X")),
                    ("ts", Json::num(e.start_us as f64)),
                    ("dur", Json::num(e.dur_us as f64)),
                    ("pid", Json::num(pid as f64)),
                    ("tid", Json::num(tid as f64)),
                    (
                        "args",
                        Json::obj(vec![
                            ("fn", Json::str(&e.function)),
                            ("inv", Json::num(e.inv as f64)),
                            ("a", Json::num(e.a as f64)),
                            ("b", Json::num(e.b as f64)),
                        ]),
                    ),
                ]);
                slices.push(((e.start_us, pid, tid, e.dur_us, e.inv), slice));
            }
        }
    }
    slices.sort_by(|a, b| a.0.cmp(&b.0));
    events.extend(slices.into_iter().map(|(_, j)| j));
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str("ms")),
    ])
    .to_string()
}

/// A span record re-read from an export (either format).
#[derive(Debug, Clone)]
struct Rec {
    kind: SpanKind,
    function: String,
    ts: u64,
    dur: u64,
}

/// Parse an exported span log, autodetecting the format: a single JSON
/// object with `traceEvents` is Chrome, anything else is JSONL.
fn parse_export(text: &str) -> Result<Vec<Rec>, String> {
    let trimmed = text.trim();
    if trimmed.is_empty() {
        return Ok(Vec::new());
    }
    let mut recs = Vec::new();
    if trimmed.starts_with('{') {
        let v = Json::parse(trimmed).map_err(|e| format!("chrome span log: {e}"))?;
        let events = v
            .get("traceEvents")
            .and_then(Json::as_arr)
            .ok_or("chrome span log: missing traceEvents array")?;
        for e in events {
            if e.str_or("ph", "") != "X" {
                continue; // metadata
            }
            let function = e
                .get("args")
                .map(|a| a.str_or("fn", ""))
                .unwrap_or("")
                .to_string();
            if let Some(kind) = SpanKind::parse(e.str_or("name", "")) {
                recs.push(Rec {
                    kind,
                    function,
                    ts: e.u64_or("ts", 0),
                    dur: e.u64_or("dur", 0),
                });
            }
        }
    } else {
        for (i, line) in trimmed.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let v = Json::parse(line).map_err(|e| format!("jsonl line {}: {e}", i + 1))?;
            let kind = SpanKind::parse(v.str_or("kind", ""))
                .ok_or_else(|| format!("jsonl line {}: unknown span kind", i + 1))?;
            recs.push(Rec {
                kind,
                function: v.str_or("fn", "").to_string(),
                ts: v.u64_or("ts", 0),
                dur: v.u64_or("dur", 0),
            });
        }
    }
    Ok(recs)
}

const TOP_N: usize = 10;

/// Summarize an exported span log: top functions by total queue wait,
/// longest cold-start streaks, and wasted-freshen counts. Deterministic
/// (metric desc, name asc) — the `repro spans <file>` payload.
pub fn summarize(text: &str) -> Result<String, String> {
    let recs = parse_export(text)?;
    let mut fns: std::collections::BTreeSet<&str> = std::collections::BTreeSet::new();
    // Per-function aggregates, all keyed through BTreeMap so iteration
    // (and thus tie handling) is name-ordered.
    let mut queue: BTreeMap<&str, (u64, u64, u64)> = BTreeMap::new(); // total, n, max
    let mut wasted: BTreeMap<&str, u64> = BTreeMap::new();
    let mut starts: BTreeMap<&str, Vec<(u64, bool)>> = BTreeMap::new(); // (ts, cold)
    let mut total = 0u64;
    for r in &recs {
        fns.insert(&r.function);
        total += 1;
        match r.kind {
            SpanKind::Queue => {
                let q = queue.entry(&r.function).or_insert((0, 0, 0));
                q.0 += r.dur;
                q.1 += 1;
                q.2 = q.2.max(r.dur);
            }
            SpanKind::FreshenWasted => {
                *wasted.entry(&r.function).or_insert(0) += 1;
            }
            SpanKind::ColdStart => starts.entry(&r.function).or_default().push((r.ts, true)),
            SpanKind::WarmStart | SpanKind::Reinit => {
                starts.entry(&r.function).or_default().push((r.ts, false))
            }
            _ => {}
        }
    }
    // Longest run of consecutive cold starts per function, by sim time.
    let mut streaks: BTreeMap<&str, (u64, u64, u64)> = BTreeMap::new(); // streak, cold, warm
    for (f, seq) in &mut starts {
        seq.sort_by_key(|&(ts, _)| ts);
        let (mut best, mut run, mut cold, mut warm) = (0u64, 0u64, 0u64, 0u64);
        for &(_, is_cold) in seq.iter() {
            if is_cold {
                run += 1;
                cold += 1;
                best = best.max(run);
            } else {
                run = 0;
                warm += 1;
            }
        }
        streaks.insert(f, (best, cold, warm));
    }

    let mut out = String::new();
    out.push_str(&format!(
        "span summary: {total} spans, {} functions\n",
        fns.len()
    ));
    let top = |m: &BTreeMap<&str, (u64, u64, u64)>| -> Vec<(String, (u64, u64, u64))> {
        let mut rows: Vec<_> = m.iter().map(|(k, v)| (k.to_string(), *v)).collect();
        rows.sort_by(|a, b| b.1 .0.cmp(&a.1 .0).then(a.0.cmp(&b.0)));
        rows.truncate(TOP_N);
        rows
    };
    if !queue.is_empty() {
        out.push_str("top queue wait (µs):\n");
        for (f, (tot, n, max)) in top(&queue) {
            out.push_str(&format!("  {f}: total={tot} n={n} max={max}\n"));
        }
    }
    let streaked: BTreeMap<&str, (u64, u64, u64)> = streaks
        .iter()
        .filter(|(_, v)| v.0 > 0)
        .map(|(k, v)| (*k, *v))
        .collect();
    if !streaked.is_empty() {
        out.push_str("cold streaks (max consecutive cold starts):\n");
        for (f, (streak, cold, warm)) in top(&streaked) {
            out.push_str(&format!("  {f}: streak={streak} cold={cold} warm={warm}\n"));
        }
    }
    if !wasted.is_empty() {
        out.push_str("wasted freshens:\n");
        let mut rows: Vec<_> = wasted.iter().map(|(k, v)| (k.to_string(), *v)).collect();
        rows.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        rows.truncate(TOP_N);
        for (f, n) in rows {
            out.push_str(&format!("  {f}: wasted={n}\n"));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sink() -> SpanSink {
        let ev = |kind: SpanKind, f: &str, ts: u64, dur: u64| SpanEvent {
            kind,
            function: f.to_string(),
            inv: 1,
            start_us: ts,
            dur_us: dur,
            a: 0,
            b: 0,
        };
        let mut s = SpanSink::default();
        s.push_group(
            "app-a".to_string(),
            vec![
                ev(SpanKind::Arrival, "app-a/f", 100, 0),
                ev(SpanKind::Queue, "app-a/f", 100, 40),
                ev(SpanKind::ColdStart, "app-a/f", 140, 500),
                ev(SpanKind::ColdStart, "app-a/f", 900, 500),
                ev(SpanKind::WarmStart, "app-a/f", 2_000, 10),
                ev(SpanKind::FreshenWasted, "app-a/f", 3_000, 0),
            ],
            0,
        );
        s.push_group(
            "app-b".to_string(),
            vec![
                ev(SpanKind::Queue, "app-b/g", 50, 900),
                ev(SpanKind::WarmStart, "app-b/g", 950, 10),
            ],
            0,
        );
        s
    }

    #[test]
    fn jsonl_lines_parse_and_cover_every_span() {
        let s = sink();
        let rows = vec![("cell-0".to_string(), &s)];
        let text = to_jsonl(&rows);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), s.len());
        for line in lines {
            let v = Json::parse(line).expect("valid json per line");
            assert!(SpanKind::parse(v.str_or("kind", "")).is_some());
            assert_eq!(v.str_or("cell", ""), "cell-0");
        }
        // Byte-stable across renders.
        assert_eq!(text, to_jsonl(&rows));
    }

    #[test]
    fn chrome_export_is_sorted_and_round_trips() {
        let s = sink();
        let rows = vec![("cell-0".to_string(), &s)];
        let text = to_chrome(&rows);
        let v = Json::parse(&text).expect("valid chrome json");
        let events = v.get("traceEvents").unwrap().as_arr().unwrap();
        let mut last = 0u64;
        let mut slices = 0;
        for e in events {
            if e.str_or("ph", "") != "X" {
                continue;
            }
            slices += 1;
            let ts = e.get("ts").and_then(Json::as_u64).expect("non-negative ts");
            assert!(e.get("dur").and_then(Json::as_u64).is_some(), "non-negative dur");
            assert!(ts >= last, "ts monotone non-decreasing");
            last = ts;
        }
        assert_eq!(slices, s.len());
    }

    #[test]
    fn summarize_reads_both_formats_identically() {
        let s = sink();
        let rows = vec![("cell-0".to_string(), &s)];
        let from_jsonl = summarize(&to_jsonl(&rows)).unwrap();
        let from_chrome = summarize(&to_chrome(&rows)).unwrap();
        assert_eq!(from_jsonl, from_chrome);
        assert!(from_jsonl.contains("top queue wait"));
        // app-b/g waited 900 µs > app-a/f's 40 µs: it ranks first.
        let qpos = from_jsonl.find("app-b/g: total=900").unwrap();
        assert!(qpos > from_jsonl.find("top queue wait").unwrap());
        assert!(from_jsonl.contains("app-a/f: streak=2 cold=2 warm=1"));
        assert!(from_jsonl.contains("app-a/f: wasted=1"));
    }

    #[test]
    fn summarize_rejects_garbage_and_accepts_empty() {
        assert!(summarize("not json").is_err());
        assert!(summarize("{\"no\": \"traceEvents\"}").is_err());
        let empty = summarize("").unwrap();
        assert!(empty.contains("0 spans"));
    }

    #[test]
    fn format_parse() {
        assert_eq!(SpanFormat::parse("jsonl"), Some(SpanFormat::Jsonl));
        assert_eq!(SpanFormat::parse("chrome"), Some(SpanFormat::Chrome));
        assert_eq!(SpanFormat::parse("x"), None);
        assert_eq!(SpanFormat::Chrome.as_str(), "chrome");
    }
}
