//! `repro` — the freshen-rs leader binary.
//!
//! See `repro help` (or [`freshen_rs::cli::USAGE`]) for commands. The heavy
//! lifting lives in the library so tests and benches share it.

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = freshen_rs::cli::run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
