//! The `repro` CLI: leader entrypoint.
//!
//! Subcommands:
//! - `experiment <id>` — regenerate a paper artifact (`fig2`, `table1`,
//!   `fig4`, `fig5`, `fig6`, `e2e`, `ablations`, `all`).
//! - `serve` — run the real-time serving engine on the AOT artifacts and
//!   print a latency/throughput report (freshen on/off A/B).
//! - `check-artifacts` — load the artifacts and run the AOT self-checks.
//! - `gen-artifacts` — write a native artifact set (manifest + weight
//!   sidecars) entirely in rust, so serve/check work offline.
//! - `trace <file>` — replay a JSON-lines invocation trace on the sim
//!   (streamed: records schedule as they are read).
//! - `azure-macro` — the platform-scale Azure-trace macro benchmark:
//!   deterministic sharded replay of a real or synthesized trace.
//! - `gen-azure-trace <out.csv>` — write a synthetic Azure-2019-schema
//!   trace CSV for offline macro runs.
//! - `spans <file>` — summarize a span log written by `--span-log`
//!   (either format): top queue waits, cold streaks, wasted freshens.
//!
//! No `clap` offline; this is a small hand-rolled parser with `--key value`
//! options.

use std::collections::HashMap;
use std::path::PathBuf;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::experiments::azure_macro::{self, AzureMacroCfg, Mitigation, Variant};
use crate::experiments::harness::parse_seed_spec;
use crate::experiments::{ablations, e2e, fig2, fig4, fig5_6, table1, SweepRunner};
use crate::platform::exec::invoke;
use crate::platform::world::{PlatformSim, World};
use crate::runtime::backend::BackendKind;
use crate::serve::{ServeConfig, ServeEngine};
use crate::simcore::Sim;
use crate::util::config::{Config, HostClass, KeepAliveKind, PlacementKind, QueueKind};
use crate::util::json::Json;
use crate::workload::macrotrace::replay::PoolMode;
use crate::workload::macrotrace::shard::TraceSource;
use crate::workload::macrotrace::synth::SynthTraceCfg;

pub const USAGE: &str = "\
freshen-rs repro — proactive serverless function resource management

USAGE:
  repro experiment <fig2|table1|fig4|fig5|fig6|e2e|baselines|prediction|ablations|all>
                   [--seed N] [--runs N] [--gap SECONDS]
                   [--seeds N|a..b|a..=b] [--parallel N]
                   # --seeds sweeps every experiment over a seed grid on
                   # --parallel worker threads; merged output is
                   # deterministic (identical for any --parallel value)
  repro azure-macro [--trace <file.csv|synth>] [--shards N] [--parallel N]
                    [--seeds N|a..b|a..=b] [--warmup-min N]
                    [--variants baseline,hist,chain,both]
                    [--pool per-app|shared]   # shared: one memory-bounded
                    #   world per shard, warm containers compete across apps
                    [--keep-alive fixed,lru,hybrid]  # keep-alive ablation axis
                    [--queue legacy,fifo,memaware]   # dispatch-queue ablation axis
                    [--placement legacy,random,rr,affinity,constrained]
                    #   placement-strategy ablation axis: which invoker
                    #   host a cold start lands on (legacy = least-loaded)
                    [--mitigation keepalive,snapshot,freshen,hybrid]
                    #   cold-start mitigation ablation axis at a fixed
                    #   memory budget: plain keep-alive, snapshot/restore
                    #   (idle expiry parks a discounted snapshot; restore
                    #   = base + page-in), predictive freshen, or snapshot
                    #   + freshen-on-restore; defaults --variants to both
                    [--host-classes name:count:mb:coldx1000:site,...]
                    #   heterogeneous hosts, e.g. cloud:4:4096:1000:local,
                    #   edge:4:1024:1600:edge — cold starts scale by
                    #   coldx1000/1000, cross-node chain edges pay the
                    #   site's netsim link latency
                    [--freshen-guard]         # abort stale freshen runs on
                    #   pressure-reclaimed containers (container-incarnation
                    #   guard; default off = legacy keep-stepping semantics)
                    [--days N]                # synth day slices with pool +
                    #   predictor state carried across day boundaries
                    [--invokers N] [--invoker-mb MB]  # cluster sizing
                    [--apps N] [--minutes N] [--trace-seed N]  # synth knobs
                    [--span-log FILE]         # export lifecycle spans (obs/):
                    #   deterministic sim-time spans, byte-identical across
                    #   the same shard/parallel grid as the metrics digest
                    [--span-format jsonl|chrome]  # JSONL (default) or
                    #   Chrome/Perfetto trace_event JSON
                    [--span-filter SUBSTR]    # only functions whose name
                    #   contains SUBSTR (shared pools: 'app/function')
                    [--span-cap N]            # per-world span ring capacity
                    [--fn-windows]            # rolling per-function telemetry
                    #   windows + per-cell top-function table
                    [--queue-aging-bound SECONDS]  # memaware queue
                    #   anti-starvation aging bound (default 30)
                    [--digest]                # print the merged-metrics
                    #   digest (one label: bytes line per grid cell) for
                    #   golden pinning in CI
                    # platform-scale Azure-trace macro benchmark; merged
                    # metrics are byte-identical for ANY --shards x
                    # --parallel combination (per-app pool), and for any
                    # --parallel at fixed --shards (shared pool)
  repro gen-azure-trace <out.csv> [--apps N] [--minutes N] [--seed N]
  repro serve [--requests N] [--artifacts DIR] [--no-freshen]
              [--backend native|pjrt]  # executor: pure-rust nn (default) or PJRT
              [--no-pad]               # native only: run exact batch sizes
              [--listen ADDR]          # HTTP mode: POST /classify, /freshen; GET /stats
  repro check-artifacts [--artifacts DIR] [--backend native|pjrt]
  repro gen-artifacts [DIR] [--tiny] [--input-dim N] [--hidden 512,256]
              [--classes N] [--batches 1,4,8,16] [--seed N]
              # DIR defaults to 'artifacts'; --tiny writes a small smoke set
  repro trace <file.jsonl> [--config file.json]
              [--span-log FILE] [--span-format jsonl|chrome]
              [--span-filter SUBSTR] [--span-cap N]
  repro spans <file>
              # summarize a span log written by --span-log (either
              # format): top queue waits, cold streaks, wasted freshens
  repro gen-trace <out.jsonl> [--functions N] [--horizon SECONDS] [--seed N]
  repro lint [--root DIR] [--rules]
              # simlint: the determinism static-analysis pass over the
              # crate's own sources (D001..D007); nonzero exit on findings.
              # --rules prints the rule catalog and exits.
  repro help
";

/// Parsed `--key value` options (plus positionals).
pub struct Opts {
    pub positional: Vec<String>,
    pub flags: HashMap<String, String>,
}

/// Flags that never take a value — without this list the generic parser
/// would swallow a following positional as the flag's value
/// (`gen-artifacts --tiny DIR` must keep DIR positional).
const BOOL_FLAGS: &[&str] =
    &["no-freshen", "tiny", "no-pad", "freshen-guard", "rules", "fn-windows"];

pub fn parse_args(args: &[String]) -> Opts {
    let mut positional = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(key) = a.strip_prefix("--") {
            if !BOOL_FLAGS.contains(&key)
                && i + 1 < args.len()
                && !args[i + 1].starts_with("--")
            {
                flags.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            positional.push(a.clone());
            i += 1;
        }
    }
    Opts { positional, flags }
}

impl Opts {
    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.get(key).map(|v| v == "true").unwrap_or(false)
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }
}

/// CLI entry; `args` excludes the binary name.
pub fn run(args: &[String]) -> Result<()> {
    let opts = parse_args(args);
    match opts.positional.first().map(String::as_str) {
        Some("experiment") => experiment(&opts),
        Some("serve") => serve(&opts),
        Some("check-artifacts") => check_artifacts(&opts),
        Some("gen-artifacts") => gen_artifacts(&opts),
        Some("trace") => trace(&opts),
        Some("gen-trace") => gen_trace(&opts),
        Some("azure-macro") => azure_macro_cmd(&opts),
        Some("gen-azure-trace") => gen_azure_trace(&opts),
        Some("spans") => spans(&opts),
        Some("lint") => lint(&opts),
        Some("help") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

fn experiment(opts: &Opts) -> Result<()> {
    let id = opts
        .positional
        .get(1)
        .context("experiment id required")?
        .as_str();
    let seed = opts.u64("seed", 2020);
    // Multi-seed sweep grid: `--seeds a..b` overrides `--seed`; without it
    // every experiment runs its historical single-seed configuration.
    let seeds: Vec<u64> = match opts.flags.get("seeds") {
        Some(spec) => parse_seed_spec(spec)
            .with_context(|| format!("bad --seeds '{spec}' (forms: N, a..b, a..=b)"))?,
        None => vec![seed],
    };
    let runner = SweepRunner::new(opts.u64("parallel", 1) as usize);
    match id {
        "fig2" => fig2::run_multi(&seeds, &runner).print(),
        "table1" => {
            table1::run_multi(opts.u64("runs", 20_000) as usize, &seeds, &runner).print()
        }
        "fig4" => fig4::run_multi(&seeds, &runner).print(),
        "fig5" => fig5_6::run_multi(fig5_6::Placement::Cloud, &seeds, &runner).print(),
        "fig6" => fig5_6::run_multi(fig5_6::Placement::Edge50, &seeds, &runner).print(),
        "e2e" => e2e::run_multi(&seeds, opts.u64("runs", 60) as usize, &runner).print(),
        "baselines" => {
            crate::experiments::baselines::run_multi(
                opts.u64("runs", 50) as usize,
                opts.u64("gap", 120) as f64,
                &seeds,
                &runner,
            )
            .print()
        }
        "prediction" => crate::experiments::prediction::run_multi(&seeds, &runner).print(),
        "ablations" => {
            ablations::print_lead(&ablations::lead_time_multi(
                &[-200, -100, 0, 100, 500, 1000, 2000, 5000],
                20,
                &seeds,
                &runner,
            ));
            ablations::print_confidence(&ablations::confidence_multi(
                &[0.0, 0.25, 0.5, 0.75, 1.0],
                40,
                &seeds,
                &runner,
            ));
            ablations::print_ttl(&ablations::ttl_sweep_multi(
                &[0.0, 1.0, 5.0, 10.0, 30.0, 60.0],
                48,
                &seeds,
                &runner,
            ));
        }
        "all" => {
            fig2::run_multi(&seeds, &runner).print();
            table1::run_multi(opts.u64("runs", 20_000) as usize, &seeds, &runner).print();
            fig4::run_multi(&seeds, &runner).print();
            fig5_6::run_multi(fig5_6::Placement::Cloud, &seeds, &runner).print();
            fig5_6::run_multi(fig5_6::Placement::Edge50, &seeds, &runner).print();
            e2e::run_multi(&seeds, opts.u64("runs", 60) as usize, &runner).print();
            crate::experiments::baselines::run_multi(50, 120.0, &seeds, &runner).print();
            crate::experiments::prediction::run_multi(&seeds, &runner).print();
        }
        other => bail!("unknown experiment '{other}'"),
    }
    Ok(())
}

fn artifacts_dir(opts: &Opts) -> PathBuf {
    PathBuf::from(opts.str("artifacts", "artifacts"))
}

fn backend_kind(opts: &Opts) -> Result<BackendKind> {
    BackendKind::parse(&opts.str("backend", "native"))
}

fn serve(opts: &Opts) -> Result<()> {
    let dir = artifacts_dir(opts);
    let requests = opts.u64("requests", 64) as usize;
    let freshen = !opts.flag("no-freshen");
    let backend = backend_kind(opts)?;
    let pad_to_aot = !opts.flag("no-pad");
    if !pad_to_aot && backend == BackendKind::Pjrt {
        bail!("--no-pad needs the native backend (PJRT executables have fixed batch sizes)");
    }
    let cfg = ServeConfig {
        freshen,
        backend,
        pad_to_aot,
        ..ServeConfig::default()
    };
    println!(
        "starting serve engine: {} workers, freshen={}, backend={}{}, artifacts={}",
        cfg.workers,
        freshen,
        backend.as_str(),
        if pad_to_aot { "" } else { " (no-pad)" },
        dir.display()
    );
    let engine = ServeEngine::start(dir, cfg).context("starting engine")?;
    // HTTP mode: serve until interrupted.
    if let Some(addr) = opts.flags.get("listen") {
        let engine = std::sync::Arc::new(engine);
        let server = crate::serve::http::HttpServer::bind(std::sync::Arc::clone(&engine), addr)?;
        println!(
            "listening on http://{} — POST /classify, POST /freshen, GET /stats",
            server.local_addr()
        );
        return server.run();
    }
    if freshen {
        engine.freshen().join().ok();
    }
    let dim = engine.input_dim();
    let rxs: Vec<_> = (0..requests)
        .map(|i| {
            engine.submit(
                (0..dim)
                    .map(|j| ((i * 131 + j) % 23) as f32 / 23.0)
                    .collect(),
            )
        })
        .collect();
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(60))
            .context("request timed out")?;
    }
    let report = engine.shutdown();
    report.print(if freshen { "freshen" } else { "baseline" });
    Ok(())
}

fn check_artifacts(opts: &Opts) -> Result<()> {
    let dir = artifacts_dir(opts);
    let backend = backend_kind(opts)?;
    let mut classifier = crate::runtime::model::ClassifierRuntime::load_with(&dir, backend)?;
    let err = classifier.self_check()?;
    println!(
        "classifier OK on {} (backend {}, batches {:?}, max |err| {err:.2e})",
        classifier.platform_name(),
        backend.as_str(),
        classifier.manifest.batches
    );
    let mut predictor = crate::runtime::model::PredictorRuntime::load_with(&dir, backend)?;
    let err = predictor.self_check()?;
    println!("predictor OK (max |err| {err:.2e})");
    Ok(())
}

fn parse_usize_list(s: &str) -> Result<Vec<usize>> {
    s.split(',')
        .map(|t| {
            t.trim()
                .parse::<usize>()
                .with_context(|| format!("bad number '{t}' in list '{s}'"))
        })
        .collect()
}

fn gen_artifacts(opts: &Opts) -> Result<()> {
    use crate::nn::gen::GenSpec;
    let dir = PathBuf::from(
        opts.positional
            .get(1)
            .map(String::as_str)
            .unwrap_or("artifacts"),
    );
    let mut spec = if opts.flag("tiny") {
        GenSpec::tiny()
    } else {
        GenSpec::default()
    };
    if let Some(v) = opts.flags.get("input-dim") {
        spec.input_dim = v.parse().context("--input-dim")?;
    }
    if let Some(v) = opts.flags.get("hidden") {
        spec.hidden = parse_usize_list(v)?;
    }
    if let Some(v) = opts.flags.get("classes") {
        spec.classes = v.parse().context("--classes")?;
    }
    if let Some(v) = opts.flags.get("batches") {
        spec.batches = parse_usize_list(v)?;
    }
    spec.seed = opts.u64("seed", spec.seed);
    let manifest = crate::nn::gen::generate(&dir, &spec)?;
    println!(
        "wrote native artifact set to {}: {} -> {:?} -> {} classes, batches {:?}, seed {:#x}",
        dir.display(),
        manifest.input_dim,
        spec.hidden,
        manifest.classes,
        manifest.batches,
        spec.seed
    );
    Ok(())
}

fn trace(opts: &Opts) -> Result<()> {
    let path = opts.positional.get(1).context("trace file required")?;
    let file = std::fs::File::open(path).with_context(|| format!("opening {path}"))?;
    let config = match opts.flags.get("config") {
        Some(p) => {
            let text = std::fs::read_to_string(p)?;
            Config::from_json(&Json::parse(&text).context("parsing config")?)
        }
        None => Config::default(),
    };
    let mut world = World::new(config);
    if opts.flags.contains_key("span-log") {
        world.obs = crate::obs::Tracer::enabled(
            opts.u64("span-cap", crate::obs::DEFAULT_SPAN_CAP as u64) as usize,
            opts.flags.get("span-filter").cloned(),
        );
    }
    // Traced functions are deployed as paper-λs against a default store.
    let mut ep = crate::platform::endpoint::Endpoint::new(
        "store",
        crate::netsim::link::Site::Remote,
    );
    ep.store.put("ID1", 5e6, crate::util::time::SimTime::ZERO);
    world.add_endpoint(ep);
    // Stream the trace straight into the scheduler: one line in memory at
    // a time, functions deployed on first sight. (`schedule_at` accepts
    // any future time, so file order needs no sorting pass.)
    let mut sim: PlatformSim = Sim::new();
    sim.max_events = 200_000_000;
    let mut reader =
        crate::workload::trace::TraceReader::new(std::io::BufReader::new(file));
    let mut fns = std::collections::HashSet::new();
    for rec in reader.by_ref() {
        if fns.insert(rec.function.clone()) {
            world.deploy(crate::platform::function::FunctionSpec::paper_lambda(
                &rec.function,
                "traced",
                "store",
                crate::util::time::SimDuration::from_millis(20),
            ));
        }
        let f = rec.function;
        sim.schedule_at(rec.at, move |sim, w| {
            invoke(sim, w, &f);
        });
    }
    if let Some(e) = reader.io_error() {
        bail!("reading {path}: {e}");
    }
    if reader.skipped() > 0 {
        eprintln!("warning: skipped {} malformed lines", reader.skipped());
    }
    sim.run(&mut world);
    println!(
        "replayed {} invocations over {} functions",
        world.metrics.count(),
        fns.len()
    );
    if let Some(s) = world.metrics.latency_summary(None) {
        println!(
            "latency ms: p50={:.1} p95={:.1} p99={:.1} max={:.1}",
            s.p50, s.p95, s.p99, s.max
        );
    }
    println!(
        "cold starts: {}  freshen hit rate: {:.0}%",
        world.metrics.cold_starts,
        100.0 * world.metrics.freshen_hit_rate()
    );
    if let Some(out) = opts.flags.get("span-log") {
        let fmt = span_format(opts)?;
        let (events, dropped) = world.obs.drain(&world.registry.symbols);
        let mut sink = crate::obs::SpanSink::default();
        sink.push_group("trace".to_string(), events, dropped);
        let text = crate::obs::export::export(&[("trace".to_string(), &sink)], fmt);
        std::fs::write(out, text).with_context(|| format!("writing {out}"))?;
        println!(
            "wrote {} spans to {out} [{}] ({} dropped)",
            sink.len(),
            fmt.as_str(),
            sink.dropped
        );
    }
    Ok(())
}

fn gen_trace(opts: &Opts) -> Result<()> {
    let path = opts.positional.get(1).context("output file required")?;
    let functions = opts.u64("functions", 6) as usize;
    let horizon = crate::util::time::SimDuration::from_secs(opts.u64("horizon", 600));
    let mut rng = crate::util::rng::Rng::new(opts.u64("seed", 0x7ACE));
    let mut records = Vec::new();
    for f in 0..functions {
        let process = if f % 2 == 0 {
            crate::workload::generator::ArrivalProcess::Periodic {
                period: crate::util::time::SimDuration::from_secs(30 + 7 * f as u64),
                jitter: 0.03,
            }
        } else {
            crate::workload::generator::ArrivalProcess::Bursty {
                burst_len: 3,
                intra: crate::util::time::SimDuration::from_millis(250),
                off_mean_s: 60.0,
            }
        };
        for at in process.generate(horizon, &mut rng) {
            records.push(crate::workload::trace::TraceRecord {
                at,
                function: format!("fn-{f}"),
            });
        }
    }
    records.sort_by_key(|r| r.at);
    let file = std::fs::File::create(path).with_context(|| format!("creating {path}"))?;
    crate::workload::trace::write_trace(&records, file)?;
    println!(
        "wrote {} invocations over {functions} functions to {path}",
        records.len()
    );
    Ok(())
}

/// Synth-trace knobs shared by `azure-macro --trace synth` and
/// `gen-azure-trace`; `seed_key` names the flag carrying the trace seed
/// (the benchmark reserves `--seeds` for the replay seed grid).
fn synth_cfg(opts: &Opts, seed_key: &str) -> SynthTraceCfg {
    let mut cfg = SynthTraceCfg::default();
    cfg.apps = opts.u64("apps", cfg.apps as u64) as usize;
    cfg.minutes = opts.u64("minutes", cfg.minutes as u64) as usize;
    cfg.seed = opts.u64(seed_key, cfg.seed);
    cfg
}

fn azure_macro_cmd(opts: &Opts) -> Result<()> {
    let trace = opts.str("trace", "synth");
    let source = if trace == "synth" {
        TraceSource::Synth(synth_cfg(opts, "trace-seed"))
    } else {
        TraceSource::Csv(PathBuf::from(trace))
    };
    let mut cfg = AzureMacroCfg::new(source);
    cfg.shards = opts.u64("shards", cfg.shards as u64) as usize;
    cfg.warmup_minutes = opts.u64("warmup-min", cfg.warmup_minutes as u64) as usize;
    cfg.days = opts.u64("days", cfg.days as u64) as usize;
    if let Some(pool) = opts.flags.get("pool") {
        cfg.pool = PoolMode::parse(pool)
            .with_context(|| format!("unknown pool mode '{pool}' (use per-app|shared)"))?;
    }
    if let Some(list) = opts.flags.get("keep-alive") {
        cfg.policies = list
            .split(',')
            .map(|k| {
                KeepAliveKind::parse(k.trim()).with_context(|| {
                    format!("unknown keep-alive policy '{k}' (use fixed|lru|hybrid)")
                })
            })
            .collect::<Result<Vec<KeepAliveKind>>>()?;
        if cfg.policies.is_empty() {
            bail!("--keep-alive must name at least one policy");
        }
    }
    if let Some(list) = opts.flags.get("queue") {
        cfg.queues = list
            .split(',')
            .map(|q| {
                QueueKind::parse(q.trim()).with_context(|| {
                    format!("unknown queue discipline '{q}' (use legacy|fifo|memaware)")
                })
            })
            .collect::<Result<Vec<QueueKind>>>()?;
        if cfg.queues.is_empty() {
            bail!("--queue must name at least one discipline");
        }
    }
    if let Some(list) = opts.flags.get("placement") {
        cfg.placements = list
            .split(',')
            .map(|p| {
                PlacementKind::parse(p.trim()).with_context(|| {
                    format!(
                        "unknown placement strategy '{p}' \
                         (use legacy|random|rr|affinity|constrained)"
                    )
                })
            })
            .collect::<Result<Vec<PlacementKind>>>()?;
        if cfg.placements.is_empty() {
            bail!("--placement must name at least one strategy");
        }
    }
    if let Some(spec) = opts.flags.get("host-classes") {
        cfg.host_classes = Some(HostClass::parse_list(spec).with_context(|| {
            format!(
                "bad --host-classes '{spec}' \
                 (form: name:count:capacity_mb:coldx1000:site,... with site \
                 local|edge|remote)"
            )
        })?);
    }
    cfg.freshen_guard = opts.flag("freshen-guard");
    // Span tracing is enabled exactly when an export path is given — the
    // tracer stays disabled (and stdout/digests byte-identical) otherwise.
    cfg.trace_spans = opts.flags.contains_key("span-log");
    cfg.span_cap = opts.u64("span-cap", cfg.span_cap as u64) as usize;
    cfg.span_filter = opts.flags.get("span-filter").cloned();
    cfg.fn_windows = opts.flag("fn-windows");
    if let Some(secs) = opts.flags.get("queue-aging-bound") {
        cfg.queue_aging_bound = Some(secs.parse().context("--queue-aging-bound")?);
    }
    if let Some(n) = opts.flags.get("invokers") {
        cfg.invokers = Some(n.parse().context("--invokers")?);
    }
    if let Some(mb) = opts.flags.get("invoker-mb") {
        cfg.invoker_memory_mb = Some(mb.parse().context("--invoker-mb")?);
    }
    if let Some(list) = opts.flags.get("variants") {
        cfg.variants = list
            .split(',')
            .map(|v| {
                Variant::parse(v.trim()).with_context(|| {
                    format!("unknown variant '{v}' (use baseline|hist|chain|both)")
                })
            })
            .collect::<Result<Vec<Variant>>>()?;
        if cfg.variants.is_empty() {
            bail!("--variants must name at least one variant");
        }
    }
    if let Some(list) = opts.flags.get("mitigation") {
        let mits = list
            .split(',')
            .map(|m| {
                Mitigation::parse(m.trim()).with_context(|| {
                    format!(
                        "unknown mitigation '{m}' (use keepalive|snapshot|freshen|hybrid)"
                    )
                })
            })
            .collect::<Result<Vec<Mitigation>>>()?;
        if mits.is_empty() {
            bail!("--mitigation must name at least one mitigation");
        }
        cfg.mitigations = Some(mits);
        // A mitigation sweep compares mechanisms, not predictor variants:
        // default to the full system (the freshen/hybrid cells need its
        // predictors) unless --variants widens the grid explicitly.
        if !opts.flags.contains_key("variants") {
            cfg.variants = vec![Variant::Both];
        }
    }
    let seeds: Vec<u64> = match opts.flags.get("seeds") {
        Some(spec) => parse_seed_spec(spec)
            .with_context(|| format!("bad --seeds '{spec}' (forms: N, a..b, a..=b)"))?,
        None => vec![opts.u64("seed", 2020)],
    };
    let runner = SweepRunner::new(opts.u64("parallel", 1) as usize);
    let result = azure_macro::run_multi(&cfg, &seeds, &runner)?;
    result.print();
    if opts.flag("digest") {
        // The merged-metrics digest, one `label: bytes` line per grid
        // cell — what CI pins against a committed golden so a hot-path
        // change that silently moves replay output fails the smoke.
        println!("digest:\n{}", result.digest());
    }
    if let Some(path) = opts.flags.get("span-log") {
        let fmt = span_format(opts)?;
        let text = crate::obs::export::export(&result.span_rows(), fmt);
        std::fs::write(path, text).with_context(|| format!("writing {path}"))?;
        let n: usize = result.rows.iter().map(|r| r.metrics.spans.len()).sum();
        let dropped: u64 = result.rows.iter().map(|r| r.metrics.spans.dropped).sum();
        println!(
            "wrote {n} spans across {} cells to {path} [{}] ({dropped} dropped)",
            result.rows.len(),
            fmt.as_str()
        );
        println!("span digest:\n{}", result.span_digest());
    }
    Ok(())
}

/// Parse `--span-format` (default `jsonl`).
fn span_format(opts: &Opts) -> Result<crate::obs::SpanFormat> {
    let s = opts.str("span-format", "jsonl");
    crate::obs::SpanFormat::parse(&s)
        .with_context(|| format!("unknown span format '{s}' (use jsonl|chrome)"))
}

/// `repro spans <file>` — summarize a span log written by `--span-log`
/// (JSONL or Chrome trace_event format, autodetected).
fn spans(opts: &Opts) -> Result<()> {
    let path = opts.positional.get(1).context("span log file required")?;
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let summary = crate::obs::summarize(&text)
        .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    print!("{summary}");
    Ok(())
}

/// `repro lint` — run the simlint determinism pass over the crate sources.
/// `--root DIR` lints a different tree (the self-clean CI gate uses the
/// default, which resolves to this crate's `src/` at compile time).
fn lint(opts: &Opts) -> Result<()> {
    if opts.flag("rules") {
        for r in crate::analysis::rules::CATALOG {
            println!("{}  {}\n      fix: {}", r.id, r.summary, r.hint);
        }
        return Ok(());
    }
    let root = PathBuf::from(opts.str("root", concat!(env!("CARGO_MANIFEST_DIR"), "/src")));
    let (findings, files) = crate::analysis::lint_tree(&root)
        .with_context(|| format!("linting {}", root.display()))?;
    for f in &findings {
        println!("{f}");
    }
    if findings.is_empty() {
        println!("simlint: {files} files clean ({})", root.display());
        Ok(())
    } else {
        bail!(
            "simlint: {} finding(s) in {files} files — fix or add an audited \
             `// simlint: allow(rule, reason)`",
            findings.len()
        )
    }
}

fn gen_azure_trace(opts: &Opts) -> Result<()> {
    let path = opts.positional.get(1).context("output file required")?;
    let cfg = synth_cfg(opts, "seed");
    let file = std::fs::File::create(path).with_context(|| format!("creating {path}"))?;
    let summary = crate::workload::macrotrace::synth::write_csv(
        &cfg,
        std::io::BufWriter::new(file),
    )?;
    println!(
        "wrote {} invocations over {} functions / {} apps ({} minutes, seed {:#x}) to {path}",
        summary.invocations, summary.functions, summary.apps, cfg.minutes, cfg.seed
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_positionals_and_flags() {
        let args: Vec<String> = ["experiment", "fig4", "--seed", "7", "--no-freshen"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let o = parse_args(&args);
        assert_eq!(o.positional, vec!["experiment", "fig4"]);
        assert_eq!(o.u64("seed", 0), 7);
        assert!(o.flag("no-freshen"));
        assert!(!o.flag("missing"));
        assert_eq!(o.str("artifacts", "artifacts"), "artifacts");
    }

    #[test]
    fn seeds_flag_drives_a_parallel_multi_seed_sweep() {
        let args: Vec<String> = ["experiment", "fig4", "--seeds", "0..2", "--parallel", "2"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert!(run(&args).is_ok());
        let bad: Vec<String> = ["experiment", "fig4", "--seeds", "9..3"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert!(run(&bad).is_err(), "empty seed range must error");
    }

    #[test]
    fn unknown_command_errors() {
        let args = vec!["bogus".to_string()];
        assert!(run(&args).is_err());
    }

    #[test]
    fn gen_artifacts_then_check_artifacts_native() {
        let dir = std::env::temp_dir().join("freshen-cli-gen-artifacts");
        let _ = std::fs::remove_dir_all(&dir);
        let d = dir.to_str().unwrap().to_string();
        let gen: Vec<String> = vec!["gen-artifacts".into(), d.clone(), "--tiny".into()];
        assert!(run(&gen).is_ok(), "gen-artifacts failed");
        let check: Vec<String> =
            vec!["check-artifacts".into(), "--artifacts".into(), d.clone()];
        assert!(run(&check).is_ok(), "check-artifacts failed on generated set");
        let bad: Vec<String> = vec![
            "check-artifacts".into(),
            "--artifacts".into(),
            d,
            "--backend".into(),
            "tpu".into(),
        ];
        assert!(run(&bad).is_err(), "unknown backend must error");
    }

    #[test]
    fn boolean_flags_never_swallow_positionals() {
        let args: Vec<String> = ["gen-artifacts", "--tiny", "outdir", "--seed", "7"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let o = parse_args(&args);
        assert!(o.flag("tiny"));
        assert_eq!(o.positional, vec!["gen-artifacts", "outdir"]);
        assert_eq!(o.u64("seed", 0), 7);
    }

    #[test]
    fn gen_artifacts_accepts_tiny_before_the_dir() {
        // `--tiny DIR`: --tiny is a known boolean flag, so DIR stays
        // positional and the tiny set lands in DIR.
        let dir = std::env::temp_dir().join("freshen-cli-gen-tiny-first");
        let _ = std::fs::remove_dir_all(&dir);
        let d = dir.to_str().unwrap().to_string();
        let gen: Vec<String> = vec!["gen-artifacts".into(), "--tiny".into(), d];
        assert!(run(&gen).is_ok(), "gen-artifacts --tiny DIR failed");
        let m = crate::runtime::manifest::Manifest::load(&dir).expect("set written to DIR");
        assert_eq!(m.input_dim, 32, "tiny spec applied");
    }

    #[test]
    fn gen_azure_trace_then_macro_replay_from_csv() {
        let dir = std::env::temp_dir().join("freshen-cli-azure-macro");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let csv = dir.join("azure.csv").to_str().unwrap().to_string();
        let gen: Vec<String> = vec![
            "gen-azure-trace".into(),
            csv.clone(),
            "--apps".into(),
            "12".into(),
            "--minutes".into(),
            "8".into(),
            "--seed".into(),
            "5".into(),
        ];
        assert!(run(&gen).is_ok(), "gen-azure-trace failed");
        let replay: Vec<String> = vec![
            "azure-macro".into(),
            "--trace".into(),
            csv,
            "--shards".into(),
            "2".into(),
            "--parallel".into(),
            "2".into(),
            "--warmup-min".into(),
            "2".into(),
            "--variants".into(),
            "baseline,both".into(),
        ];
        assert!(run(&replay).is_ok(), "azure-macro replay failed");
    }

    #[test]
    fn azure_macro_synth_source_and_bad_variant() {
        let ok: Vec<String> = vec![
            "azure-macro".into(),
            "--apps".into(),
            "10".into(),
            "--minutes".into(),
            "6".into(),
            "--shards".into(),
            "2".into(),
            "--warmup-min".into(),
            "2".into(),
            "--variants".into(),
            "baseline".into(),
        ];
        assert!(run(&ok).is_ok(), "synth azure-macro failed");
        let bad: Vec<String> = vec![
            "azure-macro".into(),
            "--apps".into(),
            "4".into(),
            "--minutes".into(),
            "4".into(),
            "--variants".into(),
            "bogus".into(),
        ];
        assert!(run(&bad).is_err(), "unknown variant must error");
        let missing: Vec<String> = vec![
            "azure-macro".into(),
            "--trace".into(),
            "/nonexistent/azure.csv".into(),
        ];
        assert!(run(&missing).is_err(), "missing trace file must error");
    }

    #[test]
    fn azure_macro_pool_policy_and_days_flags() {
        let base = |extra: &[&str]| -> Vec<String> {
            let mut v: Vec<String> = vec![
                "azure-macro".into(),
                "--apps".into(),
                "10".into(),
                "--minutes".into(),
                "6".into(),
                "--shards".into(),
                "2".into(),
                "--warmup-min".into(),
                "2".into(),
                "--variants".into(),
                "baseline,both".into(),
            ];
            v.extend(extra.iter().map(|s| s.to_string()));
            v
        };
        assert!(
            run(&base(&["--pool", "shared", "--keep-alive", "fixed,lru,hybrid"])).is_ok(),
            "shared pool with a keep-alive ablation must run"
        );
        assert!(
            run(&base(&["--days", "2", "--pool", "shared", "--invoker-mb", "2048"])).is_ok(),
            "multi-day shared replay must run"
        );
        assert!(run(&base(&["--pool", "bogus"])).is_err(), "bad pool mode errors");
        assert!(
            run(&base(&["--keep-alive", "bogus"])).is_err(),
            "bad keep-alive policy errors"
        );
        assert!(
            run(&base(&[
                "--pool",
                "shared",
                "--queue",
                "legacy,fifo,memaware",
                "--keep-alive",
                "lru",
                "--freshen-guard",
            ]))
            .is_ok(),
            "queue-discipline ablation with the incarnation guard must run"
        );
        assert!(
            run(&base(&["--queue", "bogus"])).is_err(),
            "bad queue discipline errors"
        );
        assert!(
            run(&base(&[
                "--pool",
                "shared",
                "--placement",
                "legacy,affinity",
                "--host-classes",
                "cloud:2:4096:1000:local,edge:2:1024:1600:edge",
            ]))
            .is_ok(),
            "placement ablation over heterogeneous host classes must run"
        );
        assert!(
            run(&base(&["--placement", "bogus"])).is_err(),
            "bad placement strategy errors"
        );
        assert!(
            run(&base(&["--host-classes", "cloud:0:4096:1000:local"])).is_err(),
            "bad host-class spec errors"
        );
        let csv_days: Vec<String> = vec![
            "azure-macro".into(),
            "--trace".into(),
            "/nonexistent/azure.csv".into(),
            "--days".into(),
            "2".into(),
        ];
        assert!(run(&csv_days).is_err(), "--days on a CSV source errors");
    }

    #[test]
    fn azure_macro_mitigation_flag() {
        let base = |extra: &[&str]| -> Vec<String> {
            let mut v: Vec<String> = vec![
                "azure-macro".into(),
                "--apps".into(),
                "10".into(),
                "--minutes".into(),
                "6".into(),
                "--shards".into(),
                "2".into(),
                "--warmup-min".into(),
                "2".into(),
            ];
            v.extend(extra.iter().map(|s| s.to_string()));
            v
        };
        assert!(
            run(&base(&[
                "--pool",
                "shared",
                "--mitigation",
                "keepalive,snapshot,freshen,hybrid",
            ]))
            .is_ok(),
            "mitigation ablation must run (defaulting --variants to both)"
        );
        assert!(
            run(&base(&["--mitigation", "snapshot", "--variants", "baseline"])).is_ok(),
            "explicit --variants composes with the mitigation axis"
        );
        assert!(
            run(&base(&["--mitigation", "bogus"])).is_err(),
            "bad mitigation errors"
        );
    }

    #[test]
    fn azure_macro_span_log_windows_and_aging_bound() {
        let dir = std::env::temp_dir().join("freshen-cli-span-log");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let log = dir.join("spans.jsonl").to_str().unwrap().to_string();
        let run_args: Vec<String> = vec![
            "azure-macro".into(),
            "--apps".into(),
            "10".into(),
            "--minutes".into(),
            "6".into(),
            "--shards".into(),
            "2".into(),
            "--warmup-min".into(),
            "2".into(),
            "--variants".into(),
            "baseline".into(),
            "--queue".into(),
            "memaware".into(),
            "--queue-aging-bound".into(),
            "15".into(),
            "--fn-windows".into(),
            "--span-log".into(),
            log.clone(),
        ];
        assert!(run(&run_args).is_ok(), "span-logging azure-macro failed");
        let text = std::fs::read_to_string(&log).expect("span log written");
        assert!(!text.is_empty(), "span log has content");
        // Every line is one JSON span record.
        for line in text.lines() {
            assert!(Json::parse(line).is_ok(), "bad JSONL line: {line}");
        }
        // The summarizer reads the file back.
        let spans_args: Vec<String> = vec!["spans".into(), log.clone()];
        assert!(run(&spans_args).is_ok(), "repro spans failed");
        // Chrome export on the same run parses as one JSON document.
        let chrome = dir.join("spans.json").to_str().unwrap().to_string();
        let mut chrome_args = run_args.clone();
        let n = chrome_args.len();
        chrome_args[n - 1] = chrome.clone();
        chrome_args.push("--span-format".into());
        chrome_args.push("chrome".into());
        assert!(run(&chrome_args).is_ok(), "chrome span export failed");
        let doc = Json::parse(&std::fs::read_to_string(&chrome).unwrap())
            .expect("chrome export parses");
        assert!(doc.get("traceEvents").is_some());
        assert!(run(&vec!["spans".into(), chrome]).is_ok(), "spans on chrome format");
        // Bad format errors.
        let mut bad = run_args;
        bad.push("--span-format".into());
        bad.push("bogus".into());
        assert!(run(&bad).is_err(), "unknown span format must error");
    }

    #[test]
    fn trace_cmd_exports_spans() {
        let dir = std::env::temp_dir().join("freshen-cli-trace-spans");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let trace = dir.join("t.jsonl").to_str().unwrap().to_string();
        let log = dir.join("spans.jsonl").to_str().unwrap().to_string();
        let gen: Vec<String> = vec![
            "gen-trace".into(),
            trace.clone(),
            "--functions".into(),
            "3".into(),
            "--horizon".into(),
            "120".into(),
        ];
        assert!(run(&gen).is_ok(), "gen-trace failed");
        let replay: Vec<String> =
            vec!["trace".into(), trace, "--span-log".into(), log.clone()];
        assert!(run(&replay).is_ok(), "trace --span-log failed");
        let text = std::fs::read_to_string(&log).expect("span log written");
        assert!(text.lines().count() > 0, "trace run recorded spans");
        assert!(run(&vec!["spans".into(), log]).is_ok(), "spans summary failed");
    }

    #[test]
    fn bad_number_lists_error() {
        assert!(parse_usize_list("1,4,8").is_ok());
        assert!(parse_usize_list("1, 4 , 8").is_ok());
        assert!(parse_usize_list("1,x").is_err());
        assert!(parse_usize_list("").is_err());
    }

    #[test]
    fn help_succeeds() {
        assert!(run(&["help".to_string()]).is_ok());
        assert!(run(&[]).is_ok());
    }
}
