//! Order-independent latency aggregation for sharded replay.
//!
//! [`LatencyHist`] is a log-bucketed (HDR-style) histogram over integer
//! microseconds: 16 linear buckets below 16 µs, then 16 sub-buckets per
//! power of two (~6% relative resolution) up to `u64::MAX`. Everything in
//! it is a `u64` count, so [`LatencyHist::merge`] is a bin-wise sum —
//! commutative and associative — and a metric merged from any partition of
//! the same underlying samples (1 shard or 8, any worker interleaving) is
//! **byte-identical**. This is the property the `azure-macro` benchmark's
//! determinism contract rests on: raw-sample pooling is only deterministic
//! for a fixed grid order, while binned counts are deterministic for *any*
//! grouping.
//!
//! Quantiles are recovered from the merged bins (bucket midpoint, ~6%
//! resolution — plenty for p50/p99 reporting at platform scale).

use crate::util::time::SimDuration;

/// Linear buckets below this value (exact single-µs resolution).
const LINEAR: usize = 16;
/// Sub-buckets per power of two above the linear range.
const SUB: usize = 16;
/// Total buckets: 16 linear + 16 per octave for exponents 4..=63.
pub const BINS: usize = LINEAR + (64 - 4) * SUB;

/// Log-bucketed latency histogram with order-independent merging.
#[derive(Debug, Clone, PartialEq)]
pub struct LatencyHist {
    bins: Vec<u64>,
    count: u64,
}

impl Default for LatencyHist {
    fn default() -> LatencyHist {
        LatencyHist {
            bins: vec![0; BINS],
            count: 0,
        }
    }
}

/// Bucket index for a sample of `us` microseconds.
fn bucket_of(us: u64) -> usize {
    if us < LINEAR as u64 {
        return us as usize;
    }
    let exp = 63 - us.leading_zeros() as usize; // floor(log2), >= 4 here
    let mantissa = ((us >> (exp - 4)) & 0xF) as usize; // top 4 bits after the leading 1
    (LINEAR + (exp - 4) * SUB + mantissa).min(BINS - 1)
}

/// Representative (midpoint) value of bucket `idx`, in microseconds.
fn bucket_mid_us(idx: usize) -> f64 {
    if idx < LINEAR {
        return idx as f64; // exact: the bucket holds a single integer value
    }
    let exp = (idx - LINEAR) / SUB + 4;
    let mantissa = ((idx - LINEAR) % SUB) as f64;
    let base = (2f64).powi(i32::try_from(exp).expect("bucket exponent fits i32"));
    let lo = base * (1.0 + mantissa / SUB as f64);
    lo + base / (2.0 * SUB as f64)
}

impl LatencyHist {
    pub fn new() -> LatencyHist {
        LatencyHist::default()
    }

    /// Record one sample (microseconds).
    pub fn record_us(&mut self, us: u64) {
        self.bins[bucket_of(us)] += 1;
        self.count += 1;
    }

    /// Record one duration sample.
    pub fn record(&mut self, d: SimDuration) {
        self.record_us(d.micros());
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Bin-wise sum; commutative and associative, so the merged histogram
    /// is independent of how the samples were partitioned.
    pub fn merge(&mut self, other: &LatencyHist) {
        for (a, b) in self.bins.iter_mut().zip(other.bins.iter()) {
            *a += b;
        }
        self.count += other.count;
    }

    /// Quantile (`q` in `[0, 100]`) in milliseconds, from the bucket
    /// midpoint. Returns 0 for an empty histogram.
    pub fn quantile_ms(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((q / 100.0) * (self.count as f64 - 1.0)).round() as u64;
        let mut acc = 0u64;
        for (i, &b) in self.bins.iter().enumerate() {
            if b == 0 {
                continue;
            }
            acc += b;
            if acc > rank {
                return bucket_mid_us(i) / 1e3;
            }
        }
        bucket_mid_us(BINS - 1) / 1e3
    }

    /// Order-insensitive content fingerprint (FxHash-style fold over the
    /// bins) — what the shard-determinism regression tests compare.
    pub fn digest(&self) -> u64 {
        const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;
        let mut h = self.count;
        for &b in &self.bins {
            h = (h.rotate_left(5) ^ b).wrapping_mul(SEED);
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_monotone_and_in_range() {
        let mut prev = 0usize;
        for exp in 0..64u32 {
            let us = 1u64 << exp;
            for probe in [us, us + us / 3, us + us / 2] {
                let b = bucket_of(probe);
                assert!(b < BINS);
                assert!(b >= prev, "bucket regressed at {probe}");
                prev = b;
            }
        }
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(15), 15);
        assert_eq!(bucket_of(16), LINEAR);
        assert_eq!(bucket_of(u64::MAX), BINS - 1);
    }

    #[test]
    fn bucket_midpoint_is_within_relative_error() {
        for us in [20u64, 137, 1_000, 64_000, 1_000_000, 123_456_789] {
            let mid = bucket_mid_us(bucket_of(us));
            let rel = (mid - us as f64).abs() / us as f64;
            assert!(rel < 0.07, "us={us} mid={mid} rel={rel}");
        }
    }

    #[test]
    fn quantiles_track_samples() {
        let mut h = LatencyHist::new();
        for i in 1..=1000u64 {
            h.record_us(i * 1000); // 1..1000 ms
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile_ms(50.0);
        let p99 = h.quantile_ms(99.0);
        assert!((p50 - 500.0).abs() / 500.0 < 0.08, "p50 {p50}");
        assert!((p99 - 990.0).abs() / 990.0 < 0.08, "p99 {p99}");
        assert!(p50 < p99);
    }

    #[test]
    fn merge_is_partition_invariant() {
        let samples: Vec<u64> = (0..5000u64).map(|i| (i * 2654435761) % 10_000_000).collect();
        let mut whole = LatencyHist::new();
        for &s in &samples {
            whole.record_us(s);
        }
        // Partition into 3 odd-sized pieces, merge in a scrambled order.
        let mut parts = vec![LatencyHist::new(), LatencyHist::new(), LatencyHist::new()];
        for (i, &s) in samples.iter().enumerate() {
            parts[i % 3].record_us(s);
        }
        let mut merged = LatencyHist::new();
        for idx in [2usize, 0, 1] {
            merged.merge(&parts[idx]);
        }
        assert_eq!(whole, merged);
        assert_eq!(whole.digest(), merged.digest());
    }

    #[test]
    fn empty_hist_is_safe() {
        let h = LatencyHist::new();
        assert_eq!(h.quantile_ms(50.0), 0.0);
        assert!(h.is_empty());
        assert_eq!(h.digest(), LatencyHist::new().digest());
    }
}
