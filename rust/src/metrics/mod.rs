//! Platform metrics: latency recorders and counters.
//!
//! Each invocation contributes an [`InvocationRecord`]; the hub aggregates
//! per-function latency samples and platform-wide counters. Reports feed
//! EXPERIMENTS.md and the benches. [`hist::LatencyHist`] is the
//! order-independent (log-bucketed) aggregation the sharded macro-trace
//! replay merges across workers.

pub mod hist;

use crate::util::fxhash::FxHashMap;
use crate::util::stats::Summary;
use crate::util::time::{SimDuration, SimTime};

/// How an invocation was started.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StartKind {
    Cold,
    Warm,
    /// Served by restoring a snapshotted container: cheaper than a cold
    /// start (base + working-set page-in instead of provision + `init`),
    /// but not a warm hit. Conservation partitions completions as
    /// `cold + warm + restored`.
    Restored,
}

/// Why a container was evicted (drives the per-cause counters).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvictionCause {
    /// The keep-alive policy retired an idle container.
    Idle,
    /// Memory pressure reclaimed it to admit another cold start.
    Pressure,
}

/// Outcome record for one completed invocation.
#[derive(Debug, Clone)]
pub struct InvocationRecord {
    pub function: String,
    pub enqueued_at: SimTime,
    pub started_at: SimTime,
    pub finished_at: SimTime,
    pub start_kind: StartKind,
    /// Number of freshen resources consumed from the hook (vs self-done).
    pub freshen_hits: u32,
    pub freshen_misses: u32,
}

impl InvocationRecord {
    /// End-to-end latency (queueing + start + body).
    pub fn latency(&self) -> SimDuration {
        self.finished_at.since(self.enqueued_at)
    }

    /// Execution time only (what the provider bills).
    pub fn execution(&self) -> SimDuration {
        self.finished_at.since(self.started_at)
    }
}

/// Aggregated metrics.
#[derive(Debug, Clone, Default)]
pub struct MetricsHub {
    records: Vec<InvocationRecord>,
    /// Freshen bookkeeping.
    pub freshens_started: u64,
    pub freshens_completed: u64,
    pub freshens_wasted: u64, // predicted invocation never came
    pub cold_starts: u64,
    pub warm_starts: u64,
    /// Invocations served by restoring a snapshot (see
    /// [`StartKind::Restored`]). Zero unless `Config::snapshot.enabled`.
    pub restored_starts: u64,
    /// Warm idle containers demoted to the snapshotted state instead of
    /// being killed (the keep-alive policy's evict-to-snapshot verdict).
    pub snapshots_created: u64,
    /// Total restore latency paid, µs (base + page-in, integer-exact) —
    /// `restored_starts` restores contributed.
    pub restore_us: u64,
    /// Freshen runs launched on freshly restored containers (the hybrid
    /// mitigation's re-warm pass).
    pub freshens_on_restore: u64,
    pub evictions: u64,
    /// Evictions by cause: the keep-alive policy retired an idle
    /// container, vs. memory pressure reclaimed one to admit a cold start.
    pub evictions_idle: u64,
    pub evictions_pressure: u64,
    /// Pressure evictions that destroyed live warm state (the victim had
    /// served at least one invocation since its cold start) — the
    /// "warm kill" cost of running a contended cluster.
    pub warm_kills: u64,
    /// Peak resident container memory, MB (exact integer; tracked by the
    /// world on every charge/release).
    pub peak_resident_mb: u64,
    /// Time integral of resident container memory, in MB·microseconds
    /// (divide by 1e6 for MB·s). Integer so merged reports stay
    /// order-independent.
    pub resident_mb_us: u64,
    /// Per-app isolation re-inits (warm container swapped to a sibling
    /// function instead of cold-starting a new one).
    pub reinits: u64,
    /// Distinct invocations that ever waited in the dispatch queue
    /// (retry re-enqueues don't recount).
    pub queued_total: u64,
    /// Deepest the dispatch queue ever got.
    pub queue_peak_depth: u64,
    /// Total time invocations spent queued waiting for cluster memory,
    /// µs (integer so merged reports stay order-independent).
    pub queue_wait_us: u64,
    /// Longest single queue wait, µs.
    pub queue_wait_max_us: u64,
    /// Freshen runs aborted by the container-incarnation guard
    /// (`Config::freshen_incarnation_guard`): the run's container was
    /// pressure-reclaimed mid-flight.
    pub stale_freshen_aborts: u64,
    /// Invocations dropped explicitly because no host could EVER admit
    /// their memory charge (queueing them would strand them forever).
    /// Conservation: scheduled == completed + dropped.
    pub dropped_infeasible: u64,
    /// Times `World::note_resident_delta` clamped a negative delta that
    /// would have underflowed `resident_mb`. Always zero in a correctly
    /// paired charge/release stream (asserted by the accounting debug
    /// checks); nonzero flags a mis-paired release the release build
    /// would previously have wrapped silently.
    pub accounting_clamps: u64,
    /// Opt-in rolling per-function telemetry windows (`obs/window.rs`):
    /// disabled by default so the hot path pays one bool test; replays
    /// turn it on via `ReplayCfg::fn_windows` / `--fn-windows`.
    pub windows: crate::obs::WindowSet,
}

impl MetricsHub {
    pub fn new() -> MetricsHub {
        MetricsHub::default()
    }

    pub fn record(&mut self, rec: InvocationRecord) {
        match rec.start_kind {
            StartKind::Cold => self.cold_starts += 1,
            StartKind::Warm => self.warm_starts += 1,
            StartKind::Restored => self.restored_starts += 1,
        }
        self.records.push(rec);
    }

    pub fn records(&self) -> &[InvocationRecord] {
        &self.records
    }

    pub fn count(&self) -> usize {
        self.records.len()
    }

    /// Latency summary (ms) over all records, or for one function.
    pub fn latency_summary(&self, function: Option<&str>) -> Option<Summary> {
        let samples: Vec<SimDuration> = self
            .records
            .iter()
            .filter(|r| function.map_or(true, |f| r.function == f))
            .map(|r| r.latency())
            .collect();
        Summary::of_durations_ms(&samples)
    }

    /// Raw freshen counters across all invocations: `(resources served
    /// by the hook, total resources)`. Summable across runs — the
    /// multi-seed merges pool these instead of averaging rates.
    pub fn freshen_hit_counts(&self) -> (u64, u64) {
        self.records.iter().fold((0u64, 0u64), |(h, t), r| {
            (
                h + r.freshen_hits as u64,
                t + (r.freshen_hits + r.freshen_misses) as u64,
            )
        })
    }

    /// Freshen hit rate across all invocations (resources served by the
    /// hook / total resources).
    pub fn freshen_hit_rate(&self) -> f64 {
        let (hits, total) = self.freshen_hit_counts();
        if total == 0 {
            0.0
        } else {
            hits as f64 / total as f64
        }
    }

    /// Throughput over the recorded span, invocations/sec.
    pub fn throughput(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        let start = self.records.iter().map(|r| r.enqueued_at).min().unwrap();
        let end = self.records.iter().map(|r| r.finished_at).max().unwrap();
        let span = end.since(start).as_secs_f64();
        if span <= 0.0 {
            0.0
        } else {
            self.records.len() as f64 / span
        }
    }

    /// Per-function latency table, sorted by function id.
    pub fn per_function(&self) -> Vec<(String, Summary)> {
        let mut by_fn: FxHashMap<&str, Vec<SimDuration>> = FxHashMap::default();
        for r in &self.records {
            by_fn.entry(&r.function).or_default().push(r.latency());
        }
        let mut out: Vec<(String, Summary)> = by_fn
            .into_iter()
            .filter_map(|(f, xs)| Summary::of_durations_ms(&xs).map(|s| (f.to_string(), s)))
            .collect();
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(function: &str, enq: u64, start: u64, fin: u64, kind: StartKind) -> InvocationRecord {
        InvocationRecord {
            function: function.to_string(),
            enqueued_at: SimTime(enq),
            started_at: SimTime(start),
            finished_at: SimTime(fin),
            start_kind: kind,
            freshen_hits: 1,
            freshen_misses: 1,
        }
    }

    #[test]
    fn latency_and_execution() {
        let r = rec("f", 0, 500_000, 1_500_000, StartKind::Cold);
        assert_eq!(r.latency(), SimDuration::from_millis(1500));
        assert_eq!(r.execution(), SimDuration::from_millis(1000));
    }

    #[test]
    fn hub_aggregates() {
        let mut hub = MetricsHub::new();
        hub.record(rec("f", 0, 100_000, 200_000, StartKind::Cold));
        hub.record(rec("f", 0, 50_000, 100_000, StartKind::Warm));
        hub.record(rec("g", 0, 10_000, 20_000, StartKind::Warm));
        assert_eq!(hub.count(), 3);
        assert_eq!(hub.cold_starts, 1);
        assert_eq!(hub.warm_starts, 2);
        assert_eq!(hub.per_function().len(), 2);
        let f_summary = hub.latency_summary(Some("f")).unwrap();
        assert_eq!(f_summary.count, 2);
        assert!((hub.freshen_hit_rate() - 0.5).abs() < 1e-12);
        assert!(hub.throughput() > 0.0);
    }

    #[test]
    fn restored_starts_count_separately() {
        let mut hub = MetricsHub::new();
        hub.record(rec("f", 0, 100_000, 200_000, StartKind::Cold));
        hub.record(rec("f", 0, 60_000, 120_000, StartKind::Restored));
        hub.record(rec("f", 0, 5_000, 10_000, StartKind::Warm));
        assert_eq!(hub.cold_starts, 1);
        assert_eq!(hub.warm_starts, 1);
        assert_eq!(hub.restored_starts, 1);
        assert_eq!(
            hub.cold_starts + hub.warm_starts + hub.restored_starts,
            hub.count() as u64,
            "start kinds partition completions"
        );
    }

    #[test]
    fn empty_hub_is_safe() {
        let hub = MetricsHub::new();
        assert!(hub.latency_summary(None).is_none());
        assert_eq!(hub.freshen_hit_rate(), 0.0);
        assert_eq!(hub.throughput(), 0.0);
    }
}
