//! Compile-time stub of the `xla` (PJRT) bindings.
//!
//! The offline build environment lacks the PJRT shared libraries and the
//! real `xla` crate, so this stub provides the exact API surface
//! `freshen_rs::runtime` uses, with every runtime entry point returning
//! [`Error::unavailable`]. The artifact-backed tests skip when
//! `artifacts/manifest.json` is absent, so the default suite never reaches
//! these paths. Swapping in the real bindings is a Cargo `[patch]` away —
//! no source changes required in `freshen_rs`.

use std::fmt;

/// Error type matching the real crate's role in `?`-conversions: it
/// implements `std::error::Error`, so it flows into `anyhow::Error`.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    pub fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: PJRT backend unavailable (built against the vendored \
             xla stub; patch in the real `xla` crate to run AOT artifacts)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Stub of a parsed HLO module.
#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// Stub of an XLA computation graph.
#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stub of a PJRT client.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

/// Stub of a host-side literal (tensor value).
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_xs: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(Error::unavailable("Literal::to_tuple1"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

/// Stub of a device buffer.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Stub of a compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_paths_error_descriptively() {
        let e = HloModuleProto::from_text_file("x.hlo.txt").unwrap_err();
        assert!(e.to_string().contains("PJRT backend unavailable"));
        assert!(PjRtClient::cpu().is_err());
        let lit = Literal::vec1(&[1f32, 2.0]).reshape(&[2, 1]).unwrap();
        assert!(lit.to_vec::<f32>().is_err());
        assert!(lit.to_tuple1().is_err());
    }
}
