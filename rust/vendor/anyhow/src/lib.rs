//! Offline shim of the `anyhow` crate, covering the API surface this
//! workspace uses: [`Error`], [`Result`], the [`Context`] extension trait
//! on `Result`/`Option`, and the `anyhow!`/`bail!`/`ensure!` macros.
//!
//! The build environment has no registry access, so the real crate cannot
//! be fetched; this shim is drop-in compatible for the subset in use.
//! Error messages render identically: `{}` shows the outermost message,
//! `{:#}` shows the full `outer: inner: ...` context chain.

use std::error::Error as StdError;
use std::fmt;

/// `Result` with a boxed, context-carrying error. Mirrors `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-carrying error chain. Unlike the real crate this stores the
/// rendered messages rather than the live error values — callers here only
/// ever display the chain.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Build an error from a displayable message (no source).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error {
            msg: m.to_string(),
            source: None,
        }
    }

    /// Wrap `self` with an outer context message.
    pub fn context<C: fmt::Display>(self, ctx: C) -> Error {
        Error {
            msg: ctx.to_string(),
            source: Some(Box::new(self)),
        }
    }

    fn from_std(e: &(dyn StdError + 'static)) -> Error {
        Error {
            msg: e.to_string(),
            source: e.source().map(|s| Box::new(Error::from_std(s))),
        }
    }

    /// Iterate the chain outermost-first (for diagnostics).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut out = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            out.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        out.into_iter()
    }

    /// The root (innermost) message of the chain.
    pub fn root_cause(&self) -> &str {
        let mut cur = self;
        while let Some(s) = cur.source.as_deref() {
            cur = s;
        }
        &cur.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full context chain, outermost first.
            write!(f, "{}", self.msg)?;
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if self.source.is_some() {
            write!(f, "\n\nCaused by:")?;
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, "\n    {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

// Like the real crate, `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket `From` coherent
// next to core's reflexive `impl From<T> for T`.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::from_std(&e)
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))
    }

    #[test]
    fn context_chains_render_in_alternate_mode() {
        let e = io_err().context("opening config").unwrap_err();
        assert_eq!(format!("{e}"), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: gone");
        assert_eq!(e.root_cause(), "gone");
    }

    #[test]
    fn option_context_and_macros() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");

        fn fails(n: u32) -> Result<u32> {
            ensure!(n < 10, "n too big: {n}");
            if n == 7 {
                bail!("unlucky {n}");
            }
            Ok(n)
        }
        assert_eq!(fails(3).unwrap(), 3);
        assert_eq!(format!("{}", fails(7).unwrap_err()), "unlucky 7");
        assert_eq!(format!("{}", fails(11).unwrap_err()), "n too big: 11");
    }

    #[test]
    fn with_context_is_lazy_and_chains() {
        let ok: std::result::Result<u32, std::io::Error> = Ok(5);
        let v = ok
            .with_context(|| -> String { panic!("must not evaluate") })
            .unwrap();
        assert_eq!(v, 5);
        let e = io_err()
            .with_context(|| format!("step {}", 2))
            .unwrap_err();
        assert_eq!(format!("{e:#}"), "step 2: gone");
        assert_eq!(e.chain().collect::<Vec<_>>(), vec!["step 2", "gone"]);
    }
}
