//! Dispatch-subsystem integration tests.
//!
//! Four jobs:
//! 1. pin the `LegacyOneShot` default to the pre-extraction behavior
//!    (the PR 4 shared-pool digest): a default-config `azure-macro` run
//!    must be byte-identical to an explicitly legacy-configured one, and
//!    the historical digest fields must survive unchanged inside the
//!    extended digest;
//! 2. prove `FifoFair`/`MemoryAware` actually change outcomes under
//!    contention (not silently aliased to legacy) — deterministically at
//!    the platform level, and as digests at the benchmark level;
//! 3. starvation/fairness: `FifoFair` strict head-of-line bounds a large
//!    function's time-in-queue under sustained small-function pressure,
//!    and `MemoryAware`'s aging bound rescues it where pure
//!    smallest-first would park it until the pressure ends;
//! 4. the freshen container-incarnation guard: a run in flight across a
//!    pressure eviction aborts (counted) with the switch on and keeps
//!    the legacy complete-against-the-recycled-slot semantics with it
//!    off.

use freshen_rs::experiments::azure_macro::{run_multi, AzureMacroCfg, Variant};
use freshen_rs::experiments::SweepRunner;
use freshen_rs::netsim::link::Site;
use freshen_rs::platform::dispatch::{self, MemoryAware, Waiting, MEMAWARE_AGING_BOUND};
use freshen_rs::platform::endpoint::Endpoint;
use freshen_rs::platform::exec::{invoke, start_freshen};
use freshen_rs::platform::slab::InvocationSlab;
use freshen_rs::platform::symbols::Symbols;
use freshen_rs::platform::world::{PlatformSim, World};
use freshen_rs::simcore::Sim;
use freshen_rs::util::config::{Config, KeepAliveKind, QueueKind};
use freshen_rs::util::time::{SimDuration, SimTime};
use freshen_rs::workload::macrotrace::replay::PoolMode;
use freshen_rs::workload::macrotrace::shard::TraceSource;
use freshen_rs::workload::macrotrace::synth::SynthTraceCfg;

fn small_world(cfg: Config) -> World {
    let mut w = World::new(cfg);
    let mut ep = Endpoint::new("store", Site::Edge);
    ep.store.put("ID1", 1e4, SimTime::ZERO);
    w.add_endpoint(ep);
    w
}

fn run_sim(w: &mut World, f: impl FnOnce(&mut PlatformSim, &mut World)) -> PlatformSim {
    let mut sim: PlatformSim = Sim::new();
    sim.max_events = 20_000_000;
    f(&mut sim, w);
    sim.run(w);
    sim
}

fn lambda_mb(id: &str, mb: u32, dur: SimDuration) -> freshen_rs::platform::function::FunctionSpec {
    let mut spec =
        freshen_rs::platform::function::FunctionSpec::paper_lambda(id, "app", "store", dur);
    spec.memory_mb = mb;
    spec
}

// ====================================================================
// Divergence probes (platform level, fully deterministic)
// ====================================================================

/// Run five one-slot-contended functions queued behind a long holder and
/// return the order their invocations completed in.
fn contended_completion_order(queue: QueueKind, arrival_order: &[&str]) -> Vec<String> {
    let mut cfg = Config::default();
    cfg.seed = 7;
    cfg.invokers = 1;
    cfg.containers_per_invoker = 1;
    cfg.keep_alive = KeepAliveKind::LruPressure;
    cfg.queue = queue;
    cfg.freshen.enabled = false;
    let mut w = small_world(cfg);
    w.deploy(lambda_mb("hold", 256, SimDuration::from_secs(5)));
    for f in arrival_order {
        w.deploy(lambda_mb(f, 256, SimDuration::from_millis(20)));
    }
    let arrivals: Vec<String> = arrival_order.iter().map(|s| s.to_string()).collect();
    run_sim(&mut w, |sim, w| {
        invoke(sim, w, "hold");
        for (i, f) in arrivals.iter().enumerate() {
            let f = f.clone();
            sim.schedule(
                SimDuration::from_millis(1_000 + 100 * i as u64),
                move |sim, w| {
                    invoke(sim, w, &f);
                },
            );
        }
    });
    assert!(w.dispatch.is_empty(), "no stranded entries");
    w.metrics
        .records()
        .iter()
        .filter(|r| r.function != "hold")
        .map(|r| r.function.clone())
        .collect()
}

#[test]
fn fifo_completes_in_arrival_order_and_legacy_in_hash_map_order() {
    // Choose the arrival order to be the REVERSE of the hash-map drain
    // order, computed with the very discipline the executor uses — so
    // legacy and fifo are guaranteed to diverge without pinning any
    // particular hash layout.
    let names = ["qa", "qb", "qc", "qd", "qe"];
    let pop_order = |insertion: &[String]| -> Vec<String> {
        // Mint real slab handles and intern through a fresh symbol table:
        // legacy keys on interned `Rc<str>` names whose Fx hash equals the
        // `String` hash, so the drain order here matches the real run's.
        let mut syms = Symbols::new();
        let mut slab: InvocationSlab<()> = InvocationSlab::new();
        let mut d = dispatch::build(QueueKind::LegacyOneShot, MEMAWARE_AGING_BOUND);
        let mut ids = Vec::new();
        for (i, f) in insertion.iter().enumerate() {
            let function = syms.intern(f);
            let inv = slab.insert_with(|_, _| ());
            ids.push(inv);
            d.enqueue(
                Waiting {
                    inv,
                    seq: i as u64,
                    function,
                    charge_mb: 256,
                    enqueued_at: SimTime::ZERO,
                },
                &syms,
            );
        }
        let mut order = Vec::new();
        while let Some(inv) = d.next_candidate(SimTime::ZERO, &[]) {
            let i = ids.iter().position(|&id| id == inv).expect("known handle");
            order.push(insertion[i].clone());
        }
        order
    };
    let seed_order: Vec<String> = names.iter().map(|s| s.to_string()).collect();
    let mut arrival: Vec<String> = pop_order(&seed_order);
    arrival.reverse();
    let arrival_refs: Vec<&str> = arrival.iter().map(String::as_str).collect();
    // The map order the real run will see (keys inserted in arrival
    // order — exactly what the executor's enqueues do).
    let expected_legacy = pop_order(&arrival);

    let fifo = contended_completion_order(QueueKind::FifoFair, &arrival_refs);
    assert_eq!(fifo, arrival, "FifoFair must complete in global arrival order");

    let legacy = contended_completion_order(QueueKind::LegacyOneShot, &arrival_refs);
    assert_eq!(
        legacy, expected_legacy,
        "LegacyOneShot must drain in hash-map iteration order"
    );
    assert_ne!(
        legacy, fifo,
        "the probe arrival order was built to separate legacy from fifo"
    );
}

#[test]
fn memaware_completes_smallest_charge_first_under_contention() {
    let mut cfg = Config::default();
    cfg.seed = 7;
    cfg.invokers = 1;
    cfg.invoker_memory_mb = Some(256);
    cfg.memory_accounting = freshen_rs::util::config::MemoryAccounting::FunctionMb;
    cfg.keep_alive = KeepAliveKind::LruPressure;
    cfg.freshen.enabled = false;
    let run = |queue: QueueKind| -> Vec<String> {
        let mut cfg = cfg.clone();
        cfg.queue = queue;
        let mut w = small_world(cfg);
        w.deploy(lambda_mb("hold", 256, SimDuration::from_secs(5)));
        // Any two of these exceed the 256 MB host, so placements are
        // strictly sequential and completion order IS drain order.
        w.deploy(lambda_mb("big", 256, SimDuration::from_millis(20)));
        w.deploy(lambda_mb("mid", 224, SimDuration::from_millis(20)));
        w.deploy(lambda_mb("small", 192, SimDuration::from_millis(20)));
        run_sim(&mut w, |sim, w| {
            invoke(sim, w, "hold");
            // Arrival order big → mid → small, the reverse of charge
            // order.
            for (i, f) in ["big", "mid", "small"].iter().enumerate() {
                let f = f.to_string();
                sim.schedule(
                    SimDuration::from_millis(1_000 + 100 * i as u64),
                    move |sim, w| {
                        invoke(sim, w, &f);
                    },
                );
            }
        });
        assert!(w.dispatch.is_empty());
        w.metrics
            .records()
            .iter()
            .filter(|r| r.function != "hold")
            .map(|r| r.function.clone())
            .collect()
    };
    assert_eq!(run(QueueKind::FifoFair), vec!["big", "mid", "small"]);
    assert_eq!(
        run(QueueKind::MemoryAware),
        vec!["small", "mid", "big"],
        "MemoryAware drains smallest charge first"
    );
}

// ====================================================================
// Starvation / fairness under sustained pressure
// ====================================================================

/// Sustained small-function pressure: a stream of unique 160 MB lambdas
/// (unique names, so the same-function warm fast path never bypasses the
/// cross-function drain) overloads a single 256 MB host, and one 256 MB
/// "big" function arrives early. Returns `(big wait, max wait, count)`.
fn pressure_run(w_cfg: impl FnOnce(&mut World)) -> (SimDuration, SimDuration, usize) {
    let mut cfg = Config::default();
    cfg.seed = 11;
    cfg.invokers = 1;
    cfg.invoker_memory_mb = Some(256);
    cfg.memory_accounting = freshen_rs::util::config::MemoryAccounting::FunctionMb;
    cfg.keep_alive = KeepAliveKind::LruPressure;
    cfg.freshen.enabled = false;
    let mut w = small_world(cfg);
    w_cfg(&mut w);
    const SMALLS: usize = 60;
    for i in 0..SMALLS {
        w.deploy(lambda_mb(&format!("s{i}"), 160, SimDuration::from_millis(500)));
    }
    w.deploy(lambda_mb("big", 256, SimDuration::from_millis(100)));
    run_sim(&mut w, |sim, w| {
        for i in 0..SMALLS {
            let f = format!("s{i}");
            sim.schedule(SimDuration::from_millis(300 * i as u64), move |sim, w| {
                invoke(sim, w, &f);
            });
        }
        sim.schedule(SimDuration::from_millis(2_050), |sim, w| {
            invoke(sim, w, "big");
        });
    });
    assert_eq!(w.metrics.count(), SMALLS + 1, "conservation under pressure");
    assert!(w.dispatch.is_empty(), "no stranded entries");
    let big = w
        .metrics
        .records()
        .iter()
        .find(|r| r.function == "big")
        .expect("big completed");
    let big_wait = big.started_at.since(big.enqueued_at);
    (
        big_wait,
        SimDuration::from_micros(w.metrics.queue_wait_max_us),
        w.metrics.count(),
    )
}

#[test]
fn fifo_head_of_line_bounds_the_big_functions_wait() {
    let (big_wait, _, _) = pressure_run(|w| {
        w.dispatch = dispatch::build(QueueKind::FifoFair, MEMAWARE_AGING_BOUND);
    });
    // Strict FIFO: big only waits out the handful of smalls ahead of it
    // (each ~1 s cold + body), never the whole 18 s stream.
    assert!(
        big_wait >= SimDuration::from_secs(1),
        "big genuinely queued ({big_wait})"
    );
    assert!(
        big_wait <= SimDuration::from_secs(15),
        "FifoFair must bound the big function's time-in-queue ({big_wait})"
    );
}

#[test]
fn memaware_aging_bound_rescues_the_big_function() {
    // Default aging (30 s): smallest-first parks big while smalls are
    // queued, the aging bound then gives it drain priority.
    let (aged_wait, _, _) = pressure_run(|w| {
        w.dispatch = dispatch::build(QueueKind::MemoryAware, MEMAWARE_AGING_BOUND);
    });
    assert!(
        aged_wait >= MemoryAware::default().aging_bound,
        "big cannot jump the smalls before the bound ({aged_wait})"
    );
    assert!(
        aged_wait <= SimDuration::from_secs(45),
        "the aging bound must rescue big shortly after it trips ({aged_wait})"
    );
    // With the bound pushed past the horizon, pure smallest-first parks
    // big until the small stream has fully drained — the starvation the
    // bound exists to prevent.
    let (parked_wait, _, _) = pressure_run(|w| {
        w.dispatch = Box::new(MemoryAware::with_aging_bound(SimDuration::from_secs(
            100_000,
        )));
    });
    assert!(
        parked_wait > aged_wait + SimDuration::from_secs(10),
        "without the bound big waits out the whole stream \
         ({parked_wait} vs {aged_wait})"
    );
}

// ====================================================================
// Freshen container-incarnation guard
// ====================================================================

/// A freshen run in flight on `f`'s warm container when a pressure
/// eviction reclaims the container for `g`. Returns the finished world.
fn stale_freshen_world(guard: bool) -> World {
    let mut cfg = Config::default();
    cfg.seed = 7;
    cfg.invokers = 1;
    cfg.containers_per_invoker = 1;
    cfg.keep_alive = KeepAliveKind::LruPressure;
    cfg.freshen_incarnation_guard = guard;
    let mut w = World::new(cfg);
    // A Remote store: freshen's EnsureConnection + Prefetch take real
    // simulated time, so the eviction lands mid-run.
    let mut ep = Endpoint::new("store", Site::Remote);
    ep.store.put("ID1", 1e6, SimTime::ZERO);
    w.add_endpoint(ep);
    w.deploy(lambda_mb("f", 256, SimDuration::from_millis(20)));
    w.deploy(lambda_mb("g", 256, SimDuration::from_millis(20)));
    run_sim(&mut w, |sim, w| {
        invoke(sim, w, "f");
        // f's container is warm by t=2 s; launch a developer freshen,
        // then immediately steal the container for g under pressure.
        sim.schedule(SimDuration::from_secs(2), |sim, w| {
            let _ = start_freshen(sim, w, "f", None);
        });
        sim.schedule(SimDuration::from_micros(2_000_100), |sim, w| {
            invoke(sim, w, "g");
        });
    });
    w
}

#[test]
fn incarnation_guard_aborts_the_stale_run_and_counts_it() {
    let w = stale_freshen_world(true);
    assert_eq!(w.metrics.count(), 2, "both invocations completed");
    assert_eq!(
        w.metrics.evictions_pressure, 1,
        "g reclaimed f's container mid-freshen"
    );
    assert_eq!(
        w.metrics.stale_freshen_aborts, 1,
        "exactly the one in-flight run aborts"
    );
    assert_eq!(
        w.metrics.freshens_completed, 0,
        "an aborted run never completes"
    );
    let run = &w.freshen_runs[0];
    assert!(run.done, "the aborted run is closed out");
    // The stamp recorded the launch-time incarnation; the slot has moved
    // on since.
    assert!(w.containers[run.container].incarnation > run.incarnation);
}

#[test]
fn guard_off_keeps_the_legacy_keep_stepping_semantics() {
    let w = stale_freshen_world(false);
    assert_eq!(w.metrics.count(), 2);
    assert_eq!(w.metrics.evictions_pressure, 1, "same eviction as the guarded run");
    assert_eq!(w.metrics.stale_freshen_aborts, 0, "no guard, no aborts");
    assert_eq!(
        w.metrics.freshens_completed, 1,
        "legacy semantics: the stale run steps to completion against the \
         recycled slot"
    );
}

// ====================================================================
// azure-macro: legacy pinning + divergence + determinism
// ====================================================================

fn macro_cfg(shards: usize) -> AzureMacroCfg {
    let mut cfg = AzureMacroCfg::new(TraceSource::Synth(SynthTraceCfg {
        apps: 36,
        minutes: 14,
        seed: 0xDE7E_2019,
        ..SynthTraceCfg::default()
    }));
    cfg.shards = shards;
    cfg.warmup_minutes = 4;
    cfg.variants = vec![Variant::Both];
    cfg.pool = PoolMode::Shared;
    // A tight cluster so the shared pool genuinely queues. (Functions
    // the 1024 MB hosts can never admit drop explicitly — identically
    // under every discipline, so volume comparisons stay exact.)
    cfg.invokers = Some(2);
    cfg.invoker_memory_mb = Some(1024);
    cfg.policies = vec![KeepAliveKind::LruPressure];
    cfg
}

#[test]
fn default_queue_is_byte_identical_to_explicit_legacy() {
    // The PR 4 pinning: AzureMacroCfg's defaults (no queue axis, no
    // guard) must produce EXACTLY the bytes of an explicitly
    // legacy-configured grid — if the dispatch extraction had changed
    // the default path, these digests would differ. The historical
    // digest fields additionally survive as a prefix of the extended
    // digest, so pre-extraction digests remain comparable.
    let implicit = run_multi(&macro_cfg(2), &[7], &SweepRunner::new(2)).unwrap();
    let mut explicit_cfg = macro_cfg(2);
    explicit_cfg.queues = vec![QueueKind::LegacyOneShot];
    explicit_cfg.freshen_guard = false;
    let explicit = run_multi(&explicit_cfg, &[7], &SweepRunner::new(1)).unwrap();
    assert_eq!(implicit.digest(), explicit.digest());
    for row in &implicit.rows {
        assert!(row.metrics.digest().starts_with(&row.metrics.digest_pr4()));
        assert!(row.metrics.digest_pr4().starts_with(&row.metrics.digest_legacy()));
    }
    // The default config really is legacy.
    let probe = Config::default();
    assert_eq!(probe.queue, QueueKind::LegacyOneShot);
    assert!(!probe.freshen_incarnation_guard);
}

#[test]
fn fifo_and_memaware_change_contended_outcomes_and_stay_deterministic() {
    let mut cfg = macro_cfg(2);
    cfg.queues = vec![
        QueueKind::LegacyOneShot,
        QueueKind::FifoFair,
        QueueKind::MemoryAware,
    ];
    let serial = run_multi(&cfg, &[7], &SweepRunner::new(1)).unwrap();
    let parallel = run_multi(&cfg, &[7], &SweepRunner::new(4)).unwrap();
    assert_eq!(
        serial.digest(),
        parallel.digest(),
        "every discipline stays parallel-invariant at fixed shards"
    );
    assert_eq!(serial.rows.len(), 3);
    let legacy = &serial.rows[0].metrics;
    let fifo = &serial.rows[1].metrics;
    let memaware = &serial.rows[2].metrics;
    // The probe's premise: the tight shared pool genuinely queued.
    assert!(
        legacy.queued_total > 0,
        "contended config must queue (got {})",
        legacy.queued_total
    );
    // Volume is conserved whatever the discipline (feasibility drops are
    // discipline-independent)...
    assert_eq!(legacy.invocations, fifo.invocations);
    assert_eq!(legacy.invocations, memaware.invocations);
    assert_eq!(legacy.dropped_infeasible, fifo.dropped_infeasible);
    assert_eq!(legacy.dropped_infeasible, memaware.dropped_infeasible);
    // ...but the outcomes must move: not silently aliased to legacy.
    assert_ne!(
        legacy.digest(),
        fifo.digest(),
        "FifoFair must change contended outcomes"
    );
    assert_ne!(
        legacy.digest(),
        memaware.digest(),
        "MemoryAware must change contended outcomes"
    );
}
