//! Keep-alive policy integration tests.
//!
//! Two jobs: (1) pin the `FixedTtl` default to the **legacy inline
//! semantics** the trait refactor extracted from `exec.rs` (eviction at
//! exactly `idle_eviction` after the last release; LRU steal only when
//! container sharing is on; queueing otherwise), and (2) exercise the new
//! policies — `LruPressure`'s pressure-ordered eviction and the
//! stale-idle-timer cancellation bugfix.

use std::cell::Cell;
use std::rc::Rc;

use freshen_rs::netsim::link::Site;
use freshen_rs::platform::container::ContainerState;
use freshen_rs::platform::endpoint::Endpoint;
use freshen_rs::platform::exec::invoke;
use freshen_rs::platform::world::{PlatformSim, World};
use freshen_rs::simcore::Sim;
use freshen_rs::util::config::{Config, KeepAliveKind};
use freshen_rs::util::time::{SimDuration, SimTime};

fn small_world(cfg: Config) -> World {
    let mut w = World::new(cfg);
    let mut ep = Endpoint::new("store", Site::Edge);
    ep.store.put("ID1", 1e4, SimTime::ZERO); // small object: fast bodies
    w.add_endpoint(ep);
    w
}

fn lambda(id: &str) -> freshen_rs::platform::function::FunctionSpec {
    freshen_rs::platform::function::FunctionSpec::paper_lambda(
        id,
        "app",
        "store",
        SimDuration::from_millis(20),
    )
}

fn run_sim(w: &mut World, f: impl FnOnce(&mut PlatformSim, &mut World)) -> PlatformSim {
    let mut sim: PlatformSim = Sim::new();
    sim.max_events = 10_000_000;
    f(&mut sim, w);
    sim.run(w);
    sim
}

// ====================================================================
// The stale-timer bugfix
// ====================================================================

#[test]
fn superseded_idle_timers_are_cancelled_not_accumulated() {
    // Regression: every container release used to schedule a fresh
    // idle-eviction closure and leave the previous one pending, so a hot
    // container accumulated O(releases) no-op wheel events. Now each
    // release replaces the pending check.
    let mut cfg = Config::default();
    cfg.seed = 7;
    let mut w = small_world(cfg);
    w.deploy(lambda("f"));
    let pending_at_probe = Rc::new(Cell::new(usize::MAX));
    run_sim(&mut w, |sim, w| {
        invoke(sim, w, "f");
        sim.schedule(SimDuration::from_secs(1), |sim, w| {
            invoke(sim, w, "f");
        });
        sim.schedule(SimDuration::from_secs(2), |sim, w| {
            invoke(sim, w, "f");
        });
        // Probe after all three invocations are done but long before any
        // idle TTL: the ONLY pending events should be exactly one idle
        // check (it used to be three).
        let seen = Rc::clone(&pending_at_probe);
        sim.schedule(SimDuration::from_secs(100), move |sim, _w| {
            seen.set(sim.pending());
        });
    });
    assert_eq!(w.metrics.count(), 3);
    assert_eq!(
        pending_at_probe.get(),
        1,
        "exactly one idle check may be pending; superseded timers must be cancelled"
    );
    // The single surviving check still evicts at the TTL.
    assert_eq!(w.metrics.evictions, 1);
    assert_eq!(w.metrics.evictions_idle, 1);
}

// ====================================================================
// FixedTtl == the legacy inline behavior
// ====================================================================

#[test]
fn fixed_ttl_evicts_exactly_at_the_legacy_idle_ttl() {
    let mut cfg = Config::default();
    cfg.seed = 7;
    assert_eq!(cfg.keep_alive, KeepAliveKind::FixedTtl, "FixedTtl is the default");
    let mut w = small_world(cfg);
    w.deploy(lambda("f"));
    let warm_at_600 = Rc::new(Cell::new(false));
    let evicted_at_610 = Rc::new(Cell::new(false));
    run_sim(&mut w, |sim, w| {
        invoke(sim, w, "f");
        // The invocation releases its container well before t=10s; the
        // legacy TTL is 600s from the release. At t=600s the container
        // must still be warm (idle < 600), by t=610s it must be gone.
        let warm = Rc::clone(&warm_at_600);
        sim.schedule(SimDuration::from_secs(600), move |_sim, w| {
            warm.set(w.containers[0].state == ContainerState::Warm);
        });
        let evicted = Rc::clone(&evicted_at_610);
        sim.schedule(SimDuration::from_secs(610), move |_sim, w| {
            evicted.set(w.containers[0].state == ContainerState::Evicted);
        });
    });
    assert!(warm_at_600.get(), "no early eviction: the TTL runs from the release");
    assert!(evicted_at_610.get(), "eviction fires at release + 600s");
    assert_eq!(w.metrics.evictions_idle, 1);
    assert_eq!(w.metrics.evictions_pressure, 0);
}

#[test]
fn fixed_ttl_steals_lru_only_when_sharing_is_allowed() {
    // Legacy `steal_lru_warm` semantics: with sharing ON a full cluster
    // repurposes the LRU warm container (a pressure eviction); with
    // sharing OFF the invocation queues until an idle eviction frees the
    // slot.
    let run = |sharing: bool| {
        let mut cfg = Config::default();
        cfg.seed = 7;
        cfg.invokers = 1;
        cfg.containers_per_invoker = 1;
        cfg.allow_container_sharing = sharing;
        let mut w = small_world(cfg);
        w.deploy(lambda("f"));
        w.deploy(lambda("g"));
        run_sim(&mut w, |sim, w| {
            invoke(sim, w, "f");
            sim.schedule(SimDuration::from_secs(5), |sim, w| {
                invoke(sim, w, "g");
            });
        });
        w
    };
    let shared = run(true);
    assert_eq!(shared.metrics.count(), 2, "both ran");
    assert_eq!(shared.metrics.cold_starts, 2);
    assert_eq!(shared.metrics.evictions_pressure, 1, "g stole f's warm container");
    assert_eq!(shared.metrics.warm_kills, 1, "the victim held live warm state");
    let isolated = run(false);
    assert_eq!(isolated.metrics.count(), 2, "g ran after the idle eviction");
    assert_eq!(isolated.metrics.evictions_pressure, 0, "no steal without sharing");
    assert!(isolated.metrics.evictions_idle >= 1);
    // Queued g waited for the 600s TTL; stolen g ran right away.
    let g_shared = shared.metrics.records().iter().find(|r| r.function == "g").unwrap();
    let g_isolated = isolated.metrics.records().iter().find(|r| r.function == "g").unwrap();
    assert!(g_isolated.latency() > g_shared.latency());
}

// ====================================================================
// LruPressure
// ====================================================================

#[test]
fn lru_pressure_evicts_in_lru_order_and_never_on_idle() {
    let mut cfg = Config::default();
    cfg.seed = 7;
    cfg.invokers = 1;
    cfg.containers_per_invoker = 2;
    cfg.keep_alive = KeepAliveKind::LruPressure;
    let mut w = small_world(cfg);
    for f in ["f", "g", "h"] {
        w.deploy(lambda(f));
    }
    run_sim(&mut w, |sim, w| {
        invoke(sim, w, "f");
        sim.schedule(SimDuration::from_secs(10), |sim, w| {
            invoke(sim, w, "g");
        });
        sim.schedule(SimDuration::from_secs(20), |sim, w| {
            invoke(sim, w, "h");
        });
    });
    assert_eq!(w.metrics.count(), 3);
    // h's cold start reclaimed the LRU victim — f, not g.
    assert_eq!(w.metrics.evictions_pressure, 1);
    assert_eq!(w.metrics.warm_kills, 1);
    assert_eq!(w.metrics.evictions_idle, 0, "LruPressure never idles out");
    assert!(w.find_warm("f").is_none(), "f (LRU) was the victim");
    assert!(w.find_warm("g").is_some(), "g survived");
    assert!(w.find_warm("h").is_some(), "h runs in the reclaimed slot");
    // No idle timers: the simulation drained without a 600s tail.
}

#[test]
fn lru_pressure_drains_cross_function_queues_without_idle_timers() {
    // Regression: LruPressure arms no idle timers, and the historical
    // cross-function retry path only ran from idle evictions — so an
    // invocation queued while every container was Busy would have been
    // stranded forever. A release with no same-function queue now offers
    // the idle capacity to queued work immediately.
    let mut cfg = Config::default();
    cfg.seed = 7;
    cfg.invokers = 1;
    cfg.containers_per_invoker = 1;
    cfg.keep_alive = KeepAliveKind::LruPressure;
    let mut w = small_world(cfg);
    // A long-running f so g arrives while the only container is Busy.
    w.deploy(freshen_rs::platform::function::FunctionSpec::paper_lambda(
        "f",
        "app",
        "store",
        SimDuration::from_secs(5),
    ));
    w.deploy(lambda("g"));
    run_sim(&mut w, |sim, w| {
        invoke(sim, w, "f");
        sim.schedule(SimDuration::from_secs(1), |sim, w| {
            invoke(sim, w, "g"); // Busy cluster, no warm victim: queues
        });
    });
    assert_eq!(w.metrics.count(), 2, "the queued invocation must not be stranded");
    assert!(
        w.metrics.records().iter().any(|r| r.function == "g"),
        "g ran after f's release"
    );
    // g reclaimed f's just-idled container under pressure.
    assert_eq!(w.metrics.evictions_pressure, 1);
}

#[test]
fn policies_diverge_under_slot_contention() {
    // Two functions alternating on a one-slot cluster: FixedTtl (sharing
    // off) serializes b behind the 600s TTL, LruPressure trades cold
    // starts for immediacy. The policies must be *measurably* different —
    // the property the keep-alive ablation axis exists to expose.
    let run = |kind: KeepAliveKind| {
        let mut cfg = Config::default();
        cfg.seed = 11;
        cfg.invokers = 1;
        cfg.containers_per_invoker = 1;
        cfg.keep_alive = kind;
        let mut w = small_world(cfg);
        w.deploy(lambda("a"));
        w.deploy(lambda("b"));
        run_sim(&mut w, |sim, w| {
            for i in 0..20u64 {
                sim.schedule(SimDuration::from_secs(i * 10), |sim, w| {
                    invoke(sim, w, "a");
                });
                sim.schedule(SimDuration::from_secs(i * 10 + 5), |sim, w| {
                    invoke(sim, w, "b");
                });
            }
        });
        w
    };
    let fixed = run(KeepAliveKind::FixedTtl);
    let lru = run(KeepAliveKind::LruPressure);
    assert_eq!(fixed.metrics.count(), 40);
    assert_eq!(lru.metrics.count(), 40, "both policies conserve invocations");
    assert!(
        lru.metrics.cold_starts > fixed.metrics.cold_starts + 10,
        "LRU stealing cold-starts every switch ({} vs {})",
        lru.metrics.cold_starts,
        fixed.metrics.cold_starts
    );
    assert!(lru.metrics.warm_kills > 10);
    // FixedTtl pays in queueing latency instead.
    let slow_fixed = fixed
        .metrics
        .records()
        .iter()
        .map(|r| r.latency())
        .max()
        .unwrap();
    let slow_lru = lru.metrics.records().iter().map(|r| r.latency()).max().unwrap();
    assert!(
        slow_fixed > slow_lru,
        "queueing tail under FixedTtl ({slow_fixed}) exceeds LRU's ({slow_lru})"
    );
}

// ====================================================================
// HybridHistogram
// ====================================================================

#[test]
fn hybrid_retires_unpredictable_containers_early_and_keeps_periodic_ones() {
    // One periodic function invoked every 60s: the IAT histogram predicts
    // each next arrival, so the container survives gaps far longer than
    // the hybrid fallback TTL (60s) — every arrival after the history
    // warms up is a warm start. A one-shot function's container, by
    // contrast, is retired after the fallback TTL instead of squatting
    // for the fixed 600s.
    let mut cfg = Config::default();
    cfg.seed = 7;
    cfg.keep_alive = KeepAliveKind::HybridHistogram;
    let mut w = small_world(cfg);
    w.deploy(lambda("cron"));
    w.deploy(lambda("oneshot"));
    let oneshot_gone_at = Rc::new(Cell::new(false));
    run_sim(&mut w, |sim, w| {
        for i in 0..12u64 {
            sim.schedule(SimDuration::from_secs(i * 60), |sim, w| {
                invoke(sim, w, "cron");
            });
        }
        invoke(sim, w, "oneshot");
        // The one-shot container must be gone well before the fixed
        // 600s TTL (hybrid fallback is 60s).
        let gone = Rc::clone(&oneshot_gone_at);
        sim.schedule(SimDuration::from_secs(200), move |_sim, w| {
            gone.set(w.find_warm("oneshot").is_none());
        });
    });
    assert_eq!(w.metrics.count(), 13);
    assert!(
        oneshot_gone_at.get(),
        "unpredictable container retired after the short fallback TTL"
    );
    // The periodic function cold-started once; the predicted keep-alive
    // windows carried its container across every 60s gap that followed
    // the histogram's warmup (min_samples = 4).
    let cron_colds = w
        .metrics
        .records()
        .iter()
        .filter(|r| r.function == "cron")
        .filter(|r| r.start_kind == freshen_rs::metrics::StartKind::Cold)
        .count();
    assert!(
        cron_colds <= 5,
        "predicted windows keep the periodic container warm (saw {cron_colds} colds)"
    );
}
