//! Always-on native-backend tests: no `make artifacts`, no PJRT, no
//! registry access. Artifact sets are generated in-test by
//! `nn::gen::generate` (or loaded from the checked-in
//! `tests/fixtures/tiny_manifest`, whose blobs and check numerics were
//! produced independently by numpy — see `make_fixture.py` there), so the
//! full generate → check → serve path runs in every checkout and CI.

use std::path::{Path, PathBuf};
use std::time::Duration;

use freshen_rs::nn::gen::{self, GenSpec};
use freshen_rs::nn::Mlp;
use freshen_rs::runtime::backend::BackendKind;
use freshen_rs::runtime::manifest::Manifest;
use freshen_rs::runtime::model::{ClassifierRuntime, PredictorRuntime};
use freshen_rs::serve::{ServeConfig, ServeEngine};

fn fixture_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/tiny_manifest")
}

/// Generate a fresh artifact set under a unique temp dir.
fn gen_dir(name: &str, spec: &GenSpec) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("freshen-native-it-{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    gen::generate(&dir, spec).expect("generate artifact set");
    dir
}

#[test]
fn checked_in_fixture_passes_both_self_checks() {
    // The fixture's check numerics come from numpy float64 — the native
    // f32 kernels must reproduce them within the manifest contract.
    let dir = fixture_dir();
    let mut c = ClassifierRuntime::load(&dir).expect("load fixture classifier");
    assert_eq!(c.kind, BackendKind::Native);
    assert_eq!(c.platform_name(), "native-rust");
    let err = c.self_check().expect("classifier self-check");
    assert!(err < 1e-3, "classifier err {err}");
    let mut p = PredictorRuntime::load(&dir).expect("load fixture predictor");
    let err = p.self_check().expect("predictor self-check");
    assert!(err < 1e-4, "predictor err {err}");
}

#[test]
fn fixture_weights_load_into_the_expected_shape() {
    let m = Manifest::load(&fixture_dir()).unwrap();
    let spec = m.weights.as_ref().expect("fixture has a weights section");
    assert_eq!(spec.layers.len(), 2);
    assert_eq!(spec.mean, 0.5);
    let mlp = Mlp::load(&m).unwrap();
    assert_eq!(mlp.input_dim(), 8);
    assert_eq!(mlp.output_dim(), 3);
    assert!(mlp.layers[0].relu && !mlp.layers[1].relu);
}

#[test]
fn generated_set_serves_every_batch_and_matches_reference() {
    let spec = GenSpec::tiny();
    let dir = gen_dir("batches", &spec);
    let mut rt = ClassifierRuntime::load(&dir).unwrap();
    let dim = rt.manifest.input_dim;
    let classes = rt.manifest.classes;
    let mlp = Mlp::load(&rt.manifest).unwrap();
    for n in [1usize, 2, 3, 4] {
        let rows: Vec<Vec<f32>> = (0..n)
            .map(|i| {
                (0..dim)
                    .map(|j| ((i * 31 + j) % 17) as f32 / 17.0 - 0.3)
                    .collect()
            })
            .collect();
        let out = rt.infer(&rows).unwrap();
        assert_eq!(out.len(), n);
        for (row, got) in rows.iter().zip(out.iter()) {
            assert_eq!(got.len(), classes);
            let want = mlp.forward_reference(row);
            for (a, b) in got.iter().zip(want.iter()) {
                assert!((*a as f64 - b).abs() < 1e-4, "{a} vs reference {b}");
            }
        }
        // Pad-to-AOT-batch must not change row 0's logits.
        let single = rt.infer(&rows[..1]).unwrap();
        for (a, b) in single[0].iter().zip(out[0].iter()) {
            assert!((a - b).abs() < 1e-6, "batch-size-dependent result");
        }
    }
    assert!(rt.rows_served > 0 && rt.executions > 0);
}

#[test]
fn oversized_batches_chunk_instead_of_erroring() {
    // Regression: `infer` used to bail when rows.len() > max_batch.
    let spec = GenSpec::tiny(); // max AOT batch 4
    let dir = gen_dir("chunking", &spec);
    let mut rt = ClassifierRuntime::load(&dir).unwrap();
    assert_eq!(rt.max_batch(), 4);
    let dim = rt.manifest.input_dim;
    let n = 11; // chunks of 4 + 4 + 3
    let rows: Vec<Vec<f32>> = (0..n)
        .map(|i| (0..dim).map(|j| ((i * 13 + j) % 19) as f32 / 19.0).collect())
        .collect();
    let out = rt.infer(&rows).unwrap();
    assert_eq!(out.len(), n);
    assert_eq!(rt.executions, 3, "11 rows over max_batch 4 = 3 executions");
    assert_eq!(rt.rows_served, 11);
    assert_eq!(rt.padded_rows, 1, "the 3-row tail pads to batch 4");
    // Every chunked row matches its individually-inferred logits.
    for (i, row) in rows.iter().enumerate() {
        let single = rt.infer(std::slice::from_ref(row)).unwrap();
        for (a, b) in single[0].iter().zip(out[i].iter()) {
            assert!((a - b).abs() < 1e-6, "row {i} changed under chunking");
        }
    }
}

#[test]
fn no_pad_executes_exact_batches_with_identical_logits() {
    // Dynamic batch-size selection: the native engine runs any row count,
    // so `--no-pad` skips the pad-to-AOT policy entirely — zero padded
    // rows, one execution per chunk, and logits identical to the padded
    // path.
    let spec = GenSpec::tiny(); // AOT batches [1, 2, 4]
    let dir = gen_dir("no-pad", &spec);
    let dim;
    let padded_out;
    {
        let mut padded = ClassifierRuntime::load(&dir).unwrap();
        assert!(padded.pads_to_aot());
        dim = padded.manifest.input_dim;
        let rows: Vec<Vec<f32>> = (0..3)
            .map(|i| (0..dim).map(|j| ((i * 5 + j) % 13) as f32 / 13.0).collect())
            .collect();
        padded_out = padded.infer(&rows).unwrap();
        assert_eq!(padded.padded_rows, 1, "3 rows pad to the AOT batch of 4");
    }
    let mut exact = ClassifierRuntime::load(&dir).unwrap();
    assert!(!exact.set_pad_to_aot(false), "native backend honours no-pad");
    assert!(!exact.pads_to_aot());
    let rows: Vec<Vec<f32>> = (0..3)
        .map(|i| (0..dim).map(|j| ((i * 5 + j) % 13) as f32 / 13.0).collect())
        .collect();
    let out = exact.infer(&rows).unwrap();
    assert_eq!(exact.padded_rows, 0, "no-pad executes exactly 3 rows");
    assert_eq!(exact.executions, 1);
    for (a, b) in out.iter().flatten().zip(padded_out.iter().flatten()) {
        assert!((a - b).abs() < 1e-6, "no-pad changed the logits");
    }
    // The self-check passes either way (the probe is a 1-row batch).
    assert!(exact.self_check().is_ok());
    // And the serve CLI accepts the flag end-to-end on the native backend.
    let d = dir.to_str().unwrap().to_string();
    let run = |args: &[&str]| {
        freshen_rs::cli::run(&args.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    };
    run(&["serve", "--artifacts", &d, "--requests", "5", "--no-pad"])
        .expect("serve --no-pad");
    run(&["serve", "--artifacts", &d, "--no-pad", "--backend", "pjrt"])
        .expect_err("--no-pad must reject the PJRT backend");
}

#[test]
fn serve_engine_runs_end_to_end_on_the_native_backend() {
    let dir = gen_dir("serve", &GenSpec::tiny());
    let engine = ServeEngine::start(
        dir,
        ServeConfig {
            workers: 2,
            freshen: true,
            time_scale: 0.001,
            prefetch_ttl_s: 120.0,
            backend: BackendKind::Native,
            ..ServeConfig::default()
        },
    )
    .expect("start engine on native backend");
    assert_eq!(engine.input_dim(), 32, "engine reports the manifest's dim");
    engine.freshen().join().ok();
    let rxs: Vec<_> = (0..8)
        .map(|i| {
            engine.submit(
                (0..32)
                    .map(|j| ((i * 7 + j) % 11) as f32 / 11.0)
                    .collect(),
            )
        })
        .collect();
    for rx in rxs {
        let out = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("request served");
        assert_eq!(out.logits.len(), 5);
        assert!(out.logits.iter().all(|v| v.is_finite()));
    }
    let report = engine.shutdown();
    assert_eq!(report.requests, 8);
    assert!(report.store_puts >= 8);
}

#[test]
fn cli_gen_check_serve_cycle_is_offline_clean() {
    // The acceptance path: `repro gen-artifacts` → `repro check-artifacts`
    // → `repro serve`, all in the default build (xla stub, no python).
    let dir = std::env::temp_dir().join("freshen-native-it-cli");
    let _ = std::fs::remove_dir_all(&dir);
    let d = dir.to_str().unwrap().to_string();
    let run = |args: &[&str]| {
        freshen_rs::cli::run(&args.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    };
    run(&["gen-artifacts", &d, "--tiny"]).expect("gen-artifacts");
    run(&["check-artifacts", "--artifacts", &d]).expect("check-artifacts");
    run(&["serve", "--artifacts", &d, "--requests", "6"]).expect("serve freshen");
    run(&["serve", "--artifacts", &d, "--requests", "4", "--no-freshen"])
        .expect("serve baseline");
}

#[test]
fn pjrt_backend_is_selectable_but_unavailable_on_the_stub() {
    let dir = gen_dir("pjrt", &GenSpec::tiny());
    let err = ClassifierRuntime::load_with(&dir, BackendKind::Pjrt).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("unavailable"),
        "stub should explain PJRT is unavailable: {msg}"
    );
}
