//! Shard-determinism regression tests for the `azure-macro` benchmark.
//!
//! The acceptance property of the macro subsystem: merged metrics are
//! **byte-identical** across `--shards 1/2/8` × `--parallel 1/4` in the
//! default per-app pool mode. This is stronger than the sweep harness's
//! original contract (determinism for a fixed grid across `--parallel`):
//! the shard map itself may change and the bytes must not.
//!
//! Shared-pool mode keeps the weaker half — byte-identical for any
//! `--parallel` at a FIXED `--shards` — and is additionally required to
//! make keep-alive policy *matter*: on the default synth trace at least
//! one policy must move cold-start rate or p99 vs `FixedTtl`.

use freshen_rs::experiments::azure_macro::{run_multi, AzureMacroCfg, Mitigation, Variant};
use freshen_rs::experiments::SweepRunner;
use freshen_rs::util::config::{KeepAliveKind, MemoryAccounting};
use freshen_rs::workload::macrotrace::replay::PoolMode;
use freshen_rs::workload::macrotrace::shard::TraceSource;
use freshen_rs::workload::macrotrace::synth::SynthTraceCfg;

fn trace() -> SynthTraceCfg {
    SynthTraceCfg {
        apps: 36,
        minutes: 14,
        seed: 0xDE7E_2019,
        ..SynthTraceCfg::default()
    }
}

fn cfg(shards: usize) -> AzureMacroCfg {
    let mut cfg = AzureMacroCfg::new(TraceSource::Synth(trace()));
    cfg.shards = shards;
    cfg.warmup_minutes = 4;
    cfg.variants = vec![Variant::Baseline, Variant::Both];
    cfg
}

#[test]
fn merged_metrics_are_byte_identical_across_shards_and_parallelism() {
    let seeds = [7u64, 8];
    let reference = run_multi(&cfg(1), &seeds, &SweepRunner::new(1))
        .expect("reference run")
        .digest();
    assert!(
        reference.contains("inv="),
        "digest should carry counters: {reference}"
    );
    for shards in [1usize, 2, 8] {
        for parallel in [1usize, 4] {
            let digest = run_multi(&cfg(shards), &seeds, &SweepRunner::new(parallel))
                .expect("sharded run")
                .digest();
            assert_eq!(
                reference, digest,
                "shards={shards} parallel={parallel} diverged from the serial merge"
            );
        }
    }
}

#[test]
fn csv_replay_matches_synth_replay_byte_for_byte() {
    // The same trace via the CSV ingestion path and the direct synthesizer
    // path must merge to identical bytes — the reader round-trips exactly.
    let synth = trace();
    let dir = std::env::temp_dir().join("freshen-azure-macro-determinism");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trace.csv");
    {
        let file = std::fs::File::create(&path).unwrap();
        freshen_rs::workload::macrotrace::synth::write_csv(
            &synth,
            std::io::BufWriter::new(file),
        )
        .unwrap();
    }
    let seeds = [7u64];
    let from_synth = run_multi(&cfg(2), &seeds, &SweepRunner::new(2)).unwrap();
    let mut csv_cfg = cfg(8);
    csv_cfg.source = TraceSource::Csv(path);
    let from_csv = run_multi(&csv_cfg, &seeds, &SweepRunner::new(4)).unwrap();
    assert_eq!(from_synth.digest(), from_csv.digest());
    assert_eq!(from_synth.trace_rows, from_csv.trace_rows);
    assert_eq!(from_csv.skipped_rows, 0);
}

#[test]
fn prop_any_shard_and_parallel_combination_merges_identically() {
    // Property form: for randomized small traces, run seeds, shard counts
    // and worker counts, the merged digest always equals the serial
    // 1-shard merge. Complements the pinned 1/2/8 × 1/4 matrix above.
    use freshen_rs::testkit::prop::forall;
    forall("azure-macro shard/parallel invariance", 4, |g| {
        let trace = SynthTraceCfg {
            apps: g.usize(6, 18),
            minutes: g.usize(6, 12),
            seed: g.u64(0, u64::MAX - 1),
            ..SynthTraceCfg::default()
        };
        let seed = g.u64(0, u64::MAX - 1);
        let shards = g.usize(2, 9);
        let parallel = g.usize(2, 6);
        let mk = |n: usize| {
            let mut c = AzureMacroCfg::new(TraceSource::Synth(trace.clone()));
            c.shards = n;
            c.warmup_minutes = 2;
            c.variants = vec![Variant::Both];
            c
        };
        let reference = run_multi(&mk(1), &[seed], &SweepRunner::new(1))
            .expect("reference")
            .digest();
        let sharded = run_multi(&mk(shards), &[seed], &SweepRunner::new(parallel))
            .expect("sharded")
            .digest();
        assert_eq!(reference, sharded, "shards={shards} parallel={parallel}");
    });
}

#[test]
fn benchmark_actually_exercises_the_platform() {
    let r = run_multi(&cfg(2), &[7], &SweepRunner::new(2)).expect("run");
    let base = &r.rows[0].metrics;
    let both = &r.rows[1].metrics;
    assert!(base.invocations > 500, "trace too small: {}", base.invocations);
    assert!(base.cold_starts > 0, "cold starts must appear");
    assert_eq!(base.freshens_started, 0);
    assert!(both.freshens_completed > 0, "full system freshens");
    assert!(both.freshen_hits > 0, "freshen produces hits");
    assert!(both.p50_ms() > 0.0 && both.p99_ms() >= both.p50_ms());
    // Freshen must not lose work: both variants replay the same trace.
    assert_eq!(base.functions, both.functions);
    assert_eq!(base.apps, both.apps);
    // The per-app default is resident-memory-accounted too: one uniform
    // slot per container, peaks tracked as exact integers.
    assert!(base.peak_resident_mb > 0);
    assert!(base.resident_mb_us > 0);
    assert!(base.evictions >= base.evictions_idle + base.evictions_pressure);
}

#[test]
fn fixed_ttl_defaults_are_the_legacy_configuration() {
    // Golden guard: the default benchmark cell (per-app pool, FixedTtl,
    // uniform-slot accounting) must be EXACTLY what an explicitly legacy-
    // configured run produces — if a future change silently alters the
    // default pool model, this digest comparison trips.
    let seeds = [7u64];
    let implicit = run_multi(&cfg(2), &seeds, &SweepRunner::new(2)).unwrap();
    let mut explicit_cfg = cfg(2);
    explicit_cfg.pool = PoolMode::PerApp;
    explicit_cfg.policies = vec![KeepAliveKind::FixedTtl];
    explicit_cfg.days = 1;
    let explicit = run_multi(&explicit_cfg, &seeds, &SweepRunner::new(1)).unwrap();
    assert_eq!(implicit.digest(), explicit.digest());
    // And the legacy-format digest (the pre-refactor field set) is intact
    // inside the extended one, so historical comparisons stay possible.
    for row in &implicit.rows {
        assert!(row.metrics.digest().starts_with(&row.metrics.digest_legacy()));
    }
    // The legacy defaults really are legacy: uniform slots, fixed TTL.
    let probe = freshen_rs::util::config::Config::default();
    assert_eq!(probe.memory_accounting, MemoryAccounting::UniformSlot);
    assert_eq!(probe.keep_alive, KeepAliveKind::FixedTtl);
    assert_eq!(probe.invoker_memory_mb, None);
}

#[test]
fn shared_pool_is_parallel_invariant_and_contended() {
    let mut shared = cfg(2);
    shared.pool = PoolMode::Shared;
    let seeds = [7u64];
    let serial = run_multi(&shared, &seeds, &SweepRunner::new(1)).unwrap();
    let parallel = run_multi(&shared, &seeds, &SweepRunner::new(4)).unwrap();
    assert_eq!(
        serial.digest(),
        parallel.digest(),
        "shared pool must be byte-identical across --parallel at fixed --shards"
    );
    // Contention counters actually engage in the shared cluster.
    let base = &serial.rows[0].metrics;
    let isolated = run_multi(&cfg(2), &seeds, &SweepRunner::new(2)).unwrap();
    assert_eq!(
        base.invocations, isolated.rows[0].metrics.invocations,
        "pool mode never changes the arrival volume"
    );
    assert!(base.peak_resident_mb > 0);
}

#[test]
fn mitigation_axis_is_byte_identical_across_shards_and_parallelism() {
    // The mitigation axis obeys the same per-app determinism contract as
    // the rest of the grid: the four-cell mitigation table merges to
    // byte-identical digests for ANY --shards × --parallel combination.
    let mk = |shards: usize| {
        let mut c = cfg(shards);
        c.variants = vec![Variant::Both];
        c.mitigations = Some(Mitigation::all().to_vec());
        c
    };
    let reference = run_multi(&mk(1), &[7], &SweepRunner::new(1)).expect("reference");
    let ref_digest = reference.digest();
    assert!(
        ref_digest.contains("/snapshot:") && ref_digest.contains("/hybrid:"),
        "mitigation labels must appear: {ref_digest}"
    );
    for shards in [2usize, 8] {
        for parallel in [1usize, 4] {
            let digest = run_multi(&mk(shards), &[7], &SweepRunner::new(parallel))
                .expect("sharded run")
                .digest();
            assert_eq!(
                ref_digest, digest,
                "mitigation grid diverged at shards={shards} parallel={parallel}"
            );
        }
    }
    // The axis genuinely engages on this trace: snapshot cells park
    // containers on idle expiry, the keepalive cell stays mechanism-free.
    let by = |m: Mitigation| {
        &reference
            .rows
            .iter()
            .find(|r| r.mitigation == Some(m))
            .expect("cell present")
            .metrics
    };
    let ka = by(Mitigation::Keepalive);
    let snap = by(Mitigation::Snapshot);
    let fresh = by(Mitigation::Freshen);
    assert_eq!(ka.snapshots, 0);
    assert_eq!(ka.restored_starts, 0);
    assert_eq!(ka.freshens_started, 0);
    assert!(snap.snapshots > 0, "idle expiry must demote under the snapshot cell");
    assert_eq!(snap.freshens_started, 0);
    assert!(fresh.freshens_started > 0);
    assert_eq!(fresh.snapshots, 0);
    // All four cells replay the identical workload.
    for row in &reference.rows {
        assert_eq!(row.metrics.invocations, ka.invocations);
        assert_eq!(
            row.metrics.cold_starts + row.metrics.warm_starts + row.metrics.restored_starts,
            row.metrics.invocations,
            "start kinds partition completions in every cell"
        );
    }
}

#[test]
fn keep_alive_policy_moves_the_needle_under_a_shared_pool() {
    // Acceptance: with --pool shared, at least one keep-alive policy shows
    // a measurable cold-start-rate or p99 difference vs FixedTtl on the
    // default synth trace shape.
    let mut c = cfg(2);
    c.pool = PoolMode::Shared;
    c.variants = vec![Variant::Both];
    c.policies = vec![
        KeepAliveKind::FixedTtl,
        KeepAliveKind::LruPressure,
        KeepAliveKind::HybridHistogram,
    ];
    let r = run_multi(&c, &[7], &SweepRunner::new(2)).unwrap();
    assert_eq!(r.rows.len(), 3);
    let fixed = &r.rows[0].metrics;
    let moved = r.rows[1..].iter().any(|row| {
        row.metrics.cold_starts != fixed.cold_starts
            || (row.metrics.p99_ms() - fixed.p99_ms()).abs() > 1e-9
    });
    assert!(
        moved,
        "some policy must move cold starts or p99 vs FixedTtl under contention"
    );
    // Volume is conserved across policies regardless.
    for row in &r.rows {
        assert_eq!(row.metrics.invocations, fixed.invocations);
    }
}
