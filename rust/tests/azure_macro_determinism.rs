//! Shard-determinism regression tests for the `azure-macro` benchmark.
//!
//! The acceptance property of the macro subsystem: merged metrics are
//! **byte-identical** across `--shards 1/2/8` × `--parallel 1/4`. This is
//! stronger than the sweep harness's original contract (determinism for a
//! fixed grid across `--parallel`): the shard map itself may change and
//! the bytes must not.

use freshen_rs::experiments::azure_macro::{run_multi, AzureMacroCfg, Variant};
use freshen_rs::experiments::SweepRunner;
use freshen_rs::workload::macrotrace::shard::TraceSource;
use freshen_rs::workload::macrotrace::synth::SynthTraceCfg;

fn trace() -> SynthTraceCfg {
    SynthTraceCfg {
        apps: 36,
        minutes: 14,
        seed: 0xDE7E_2019,
        ..SynthTraceCfg::default()
    }
}

fn cfg(shards: usize) -> AzureMacroCfg {
    let mut cfg = AzureMacroCfg::new(TraceSource::Synth(trace()));
    cfg.shards = shards;
    cfg.warmup_minutes = 4;
    cfg.variants = vec![Variant::Baseline, Variant::Both];
    cfg
}

#[test]
fn merged_metrics_are_byte_identical_across_shards_and_parallelism() {
    let seeds = [7u64, 8];
    let reference = run_multi(&cfg(1), &seeds, &SweepRunner::new(1))
        .expect("reference run")
        .digest();
    assert!(
        reference.contains("inv="),
        "digest should carry counters: {reference}"
    );
    for shards in [1usize, 2, 8] {
        for parallel in [1usize, 4] {
            let digest = run_multi(&cfg(shards), &seeds, &SweepRunner::new(parallel))
                .expect("sharded run")
                .digest();
            assert_eq!(
                reference, digest,
                "shards={shards} parallel={parallel} diverged from the serial merge"
            );
        }
    }
}

#[test]
fn csv_replay_matches_synth_replay_byte_for_byte() {
    // The same trace via the CSV ingestion path and the direct synthesizer
    // path must merge to identical bytes — the reader round-trips exactly.
    let synth = trace();
    let dir = std::env::temp_dir().join("freshen-azure-macro-determinism");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trace.csv");
    {
        let file = std::fs::File::create(&path).unwrap();
        freshen_rs::workload::macrotrace::synth::write_csv(
            &synth,
            std::io::BufWriter::new(file),
        )
        .unwrap();
    }
    let seeds = [7u64];
    let from_synth = run_multi(&cfg(2), &seeds, &SweepRunner::new(2)).unwrap();
    let mut csv_cfg = cfg(8);
    csv_cfg.source = TraceSource::Csv(path);
    let from_csv = run_multi(&csv_cfg, &seeds, &SweepRunner::new(4)).unwrap();
    assert_eq!(from_synth.digest(), from_csv.digest());
    assert_eq!(from_synth.trace_rows, from_csv.trace_rows);
    assert_eq!(from_csv.skipped_rows, 0);
}

#[test]
fn prop_any_shard_and_parallel_combination_merges_identically() {
    // Property form: for randomized small traces, run seeds, shard counts
    // and worker counts, the merged digest always equals the serial
    // 1-shard merge. Complements the pinned 1/2/8 × 1/4 matrix above.
    use freshen_rs::testkit::prop::forall;
    forall("azure-macro shard/parallel invariance", 4, |g| {
        let trace = SynthTraceCfg {
            apps: g.usize(6, 18),
            minutes: g.usize(6, 12),
            seed: g.u64(0, u64::MAX - 1),
            ..SynthTraceCfg::default()
        };
        let seed = g.u64(0, u64::MAX - 1);
        let shards = g.usize(2, 9);
        let parallel = g.usize(2, 6);
        let mk = |n: usize| {
            let mut c = AzureMacroCfg::new(TraceSource::Synth(trace.clone()));
            c.shards = n;
            c.warmup_minutes = 2;
            c.variants = vec![Variant::Both];
            c
        };
        let reference = run_multi(&mk(1), &[seed], &SweepRunner::new(1))
            .expect("reference")
            .digest();
        let sharded = run_multi(&mk(shards), &[seed], &SweepRunner::new(parallel))
            .expect("sharded")
            .digest();
        assert_eq!(reference, sharded, "shards={shards} parallel={parallel}");
    });
}

#[test]
fn benchmark_actually_exercises_the_platform() {
    let r = run_multi(&cfg(2), &[7], &SweepRunner::new(2)).expect("run");
    let base = &r.variants[0].1;
    let both = &r.variants[1].1;
    assert!(base.invocations > 500, "trace too small: {}", base.invocations);
    assert!(base.cold_starts > 0, "cold starts must appear");
    assert_eq!(base.freshens_started, 0);
    assert!(both.freshens_completed > 0, "full system freshens");
    assert!(both.freshen_hits > 0, "freshen produces hits");
    assert!(both.p50_ms() > 0.0 && both.p99_ms() >= both.p50_ms());
    // Freshen must not lose work: both variants replay the same trace.
    assert_eq!(base.functions, both.functions);
    assert_eq!(base.apps, both.apps);
}
