//! Paper-shape checks at the integration level: each experiment harness
//! must reproduce the *qualitative* result the paper reports (orderings,
//! crossovers, factor ranges) — the contract EXPERIMENTS.md documents.
//! (Unit-level checks live next to each harness; these run the CLI-facing
//! configurations.)

use freshen_rs::experiments::{ablations, e2e, fig2, fig4, fig5_6, table1};
use freshen_rs::netsim::link::Site;

#[test]
fn fig2_orchestration_apps_have_more_functions() {
    let f = fig2::run(99);
    assert!(f.median_orch / f.median_all >= 2.5, "paper factor ~4x");
    // Most apps overall are tiny; most orchestration apps are not.
    let at3 = f.series.iter().find(|(x, _, _)| *x == 3.0).unwrap();
    assert!(at3.1 > 0.5, "over half of all apps have <=3 functions");
    assert!(at3.2 < 0.5, "under half of orchestration apps do");
}

#[test]
fn table1_gives_freshen_windows_of_60ms_to_1_3s() {
    let t = table1::run(4_000, 123);
    let min = t
        .rows
        .iter()
        .map(|r| r.median_s)
        .fold(f64::INFINITY, f64::min);
    let max = t.rows.iter().map(|r| r.median_s).fold(0.0, f64::max);
    // Paper: "latencies range from 60ms to 1.28s".
    assert!((0.04..=0.09).contains(&min), "min window {min}");
    assert!((0.9..=1.7).contains(&max), "max window {max}");
}

#[test]
fn fig4_log_scale_separation_and_benefit_band() {
    let f = fig4::run(7);
    let local = f.max_benefit_s(Site::Local);
    let edge = f.max_benefit_s(Site::Edge);
    let remote = f.max_benefit_s(Site::Remote);
    assert!(local < edge && edge < remote);
    // Paper band: 11ms (local) .. 622ms (remote).
    assert!(remote / local > 20.0, "orders-of-magnitude spread");
}

#[test]
fn fig5_fig6_warming_benefit_band_and_edge_dominance() {
    let cloud = fig5_6::run(fig5_6::Placement::Cloud, 11);
    let edge = fig5_6::run(fig5_6::Placement::Edge50, 11);
    // Paper: 51.22%..71.94% at large sizes; allow the simulator band.
    for f in [&cloud, &edge] {
        let b = f.large_benefit();
        assert!((0.40..=0.90).contains(&b), "large benefit {b}");
    }
    // 1KB sends see almost no benefit in either placement.
    assert!(cloud.cells[0].benefit().abs() < 0.15);
    assert!(edge.cells[0].benefit().abs() < 0.15);
}

#[test]
fn e2e_freshen_wins_without_changing_work() {
    let e = e2e::run(5, 30);
    assert!(e.freshened.all_latency.p50 < e.baseline.all_latency.p50);
    assert_eq!(e.baseline.invocations, e.freshened.invocations);
    // Freshen traffic is accounted, not hidden: total network including
    // prefetches stays within 2x of baseline.
    assert!(e.freshened.network_bytes <= 2.0 * e.baseline.network_bytes);
}

#[test]
fn ablation_lead_time_has_diminishing_returns() {
    let rows = ablations::lead_time(&[0, 1000, 4000], 12, 3);
    let at0 = rows.iter().find(|r| r.lead_ms == 0).unwrap();
    let at1s = rows.iter().find(|r| r.lead_ms == 1000).unwrap();
    let at4s = rows.iter().find(|r| r.lead_ms == 4000).unwrap();
    // 1s of lead captures most of the benefit; 4s adds little.
    assert!(at1s.latency.p50 <= at0.latency.p50);
    let gain_01 = at0.latency.p50 - at1s.latency.p50;
    let gain_14 = at1s.latency.p50 - at4s.latency.p50;
    assert!(gain_14 <= gain_01.max(1.0), "diminishing returns");
}
