//! End-to-end tests of the real-time serving engine (real threads, real
//! PJRT inference, netsim-derived latencies slept for real at 1000x
//! compression). Skips when artifacts are missing.

use std::path::{Path, PathBuf};
use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

use freshen_rs::serve::{ServeConfig, ServeEngine};

/// These tests measure real wall-clock latency; running several engines
/// concurrently on one core inverts A/B comparisons. Serialize them.
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    // The engine defaults to the native backend; artifact sets written
    // before the weights sidecar existed can only serve PJRT, so skip
    // rather than fail on them.
    match freshen_rs::runtime::manifest::Manifest::load(&dir) {
        Ok(m) if m.weights.is_some() => Some(dir),
        _ => {
            eprintln!("skipping: artifacts lack the weights sidecar; re-run `make artifacts`");
            None
        }
    }
}

fn image(seed: usize) -> Vec<f32> {
    (0..3072).map(|j| ((seed * 131 + j) % 23) as f32 / 23.0).collect()
}

fn config(freshen: bool) -> ServeConfig {
    ServeConfig {
        workers: 2,
        freshen,
        time_scale: 0.001,
        // At 1000x compression a burst takes tens of real ms = tens of
        // simulated seconds; keep the prefetch fresh across the burst.
        prefetch_ttl_s: 120.0,
        ..ServeConfig::default()
    }
}

#[test]
fn serves_requests_end_to_end() {
    let _guard = serial();
    let Some(dir) = artifacts_dir() else { return };
    let engine = ServeEngine::start(dir, config(true)).expect("start");
    let rxs: Vec<_> = (0..8).map(|i| engine.submit(image(i))).collect();
    for rx in rxs {
        let out = rx.recv_timeout(Duration::from_secs(30)).expect("outcome");
        assert_eq!(out.logits.len(), 10);
        assert!(out.latency > Duration::ZERO);
    }
    let report = engine.shutdown();
    assert_eq!(report.requests, 8);
    assert!(report.latency_ms.is_some());
    assert!(report.store_puts >= 8);
}

#[test]
fn freshen_reduces_serving_latency() {
    let _guard = serial();
    let Some(dir) = artifacts_dir() else { return };

    // Baseline: no freshen — every request refetches the model and pays
    // cold-connection costs.
    let base = ServeEngine::start(dir.clone(), config(false)).expect("start");
    let rxs: Vec<_> = (0..6).map(|i| base.submit(image(i))).collect();
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(30)).expect("outcome");
    }
    let base_report = base.shutdown();

    // Freshen: hook runs before the burst.
    let eng = ServeEngine::start(dir, config(true)).expect("start");
    eng.freshen().join().expect("freshen run");
    let rxs: Vec<_> = (0..6).map(|i| eng.submit(image(i))).collect();
    let mut hits = 0;
    for rx in rxs {
        let out = rx.recv_timeout(Duration::from_secs(30)).expect("outcome");
        if matches!(
            out.fetch_served,
            freshen_rs::serve::fr::Served::ByFreshen | freshen_rs::serve::fr::Served::AfterWait
        ) {
            hits += 1;
        }
    }
    let fresh_report = eng.shutdown();

    assert!(hits >= 5, "most fetches served by freshen, got {hits}");
    let b = base_report.latency_ms.as_ref().unwrap().p50;
    let f = fresh_report.latency_ms.as_ref().unwrap().p50;
    assert!(
        f < b,
        "freshened p50 {f:.2}ms should beat baseline p50 {b:.2}ms"
    );
    // Network traffic reduced: fewer store GETs than requests.
    assert!(fresh_report.store_gets < base_report.store_gets);
}

#[test]
fn logits_match_between_modes() {
    let _guard = serial();
    // Freshen must not change results, only latency.
    let Some(dir) = artifacts_dir() else { return };
    let a = ServeEngine::start(dir.clone(), config(false)).expect("start");
    let la = a
        .submit(image(3))
        .recv_timeout(Duration::from_secs(30))
        .unwrap()
        .logits;
    a.shutdown();
    let b = ServeEngine::start(dir, config(true)).expect("start");
    b.freshen().join().unwrap();
    let lb = b
        .submit(image(3))
        .recv_timeout(Duration::from_secs(30))
        .unwrap()
        .logits;
    b.shutdown();
    for (x, y) in la.iter().zip(lb.iter()) {
        assert!((x - y).abs() < 1e-5, "{x} vs {y}");
    }
}

#[test]
fn http_front_end_serves_classify_and_stats() {
    let _guard = serial();
    use freshen_rs::serve::http::HttpServer;
    use std::io::{Read, Write};
    use std::sync::Arc;

    let Some(dir) = artifacts_dir() else { return };
    let engine = Arc::new(ServeEngine::start(dir, config(true)).expect("start"));
    let server = HttpServer::bind(Arc::clone(&engine), "127.0.0.1:0").expect("bind");
    let addr = server.local_addr();
    let stop = server.stopper();
    let h = std::thread::spawn(move || server.run());

    let request = |req: String| -> String {
        let mut s = std::net::TcpStream::connect(addr).expect("connect");
        s.write_all(req.as_bytes()).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    };

    // Health.
    let health = request("GET /healthz HTTP/1.1\r\n\r\n".into());
    assert!(health.starts_with("HTTP/1.1 200"), "{health}");

    // Freshen, then classify with an explicit image body.
    let fresh = request("POST /freshen HTTP/1.1\r\nContent-Length: 0\r\n\r\n".into());
    assert!(fresh.starts_with("HTTP/1.1 202"), "{fresh}");
    std::thread::sleep(Duration::from_millis(300)); // let the hook finish

    let img: Vec<String> = (0..3072).map(|j| format!("{:.3}", (j % 7) as f32 / 7.0)).collect();
    let body = format!("{{\"image\": [{}]}}", img.join(","));
    let resp = request(format!(
        "POST /classify HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    ));
    assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
    assert!(resp.contains("\"logits\""), "{resp}");
    assert!(resp.contains("latency_ms"), "{resp}");

    // Malformed body -> 400.
    let bad = request(
        "POST /classify HTTP/1.1\r\nContent-Length: 7\r\n\r\nnotjson".to_string(),
    );
    assert!(bad.starts_with("HTTP/1.1 400"), "{bad}");

    // Unknown route -> 404.
    let nf = request("GET /nope HTTP/1.1\r\n\r\n".into());
    assert!(nf.starts_with("HTTP/1.1 404"), "{nf}");

    // Stats reflect the served request.
    let stats = request("GET /stats HTTP/1.1\r\n\r\n".into());
    assert!(stats.starts_with("HTTP/1.1 200"), "{stats}");
    assert!(stats.contains("\"requests\""));

    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    h.join().unwrap().unwrap();
}
