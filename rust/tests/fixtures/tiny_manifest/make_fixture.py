"""Generate the checked-in tiny_manifest fixture for rust weight-loading tests.

Independent of rust/src/nn/gen.rs: values come from numpy, check numerics
from a float64 naive forward — the rust native backend must reproduce them
within the manifest contract (1e-3 classifier, 1e-4 predictor).
"""
import json, os
import numpy as np

out = os.path.dirname(os.path.abspath(__file__))
rng = np.random.default_rng(20260801)
dims = [(8, 6), (6, 3)]
MEAN, STD = 0.5, 0.25

layers, params = [], []
for i, (din, dout) in enumerate(dims):
    w = (rng.standard_normal((din, dout)) * np.sqrt(2.0 / din)).astype("<f4")
    b = rng.uniform(-0.05, 0.05, dout).astype("<f4")
    w.tofile(os.path.join(out, f"layer{i}.w.bin"))
    b.tofile(os.path.join(out, f"layer{i}.b.bin"))
    params.append((w, b))
    layers.append({"in": din, "out": dout, "relu": i < len(dims) - 1,
                   "weights": f"layer{i}.w.bin", "bias": f"layer{i}.b.bin"})

def forward(row):
    h = (np.asarray(row, dtype=np.float64) - MEAN) / STD
    for i, (w, b) in enumerate(params):
        h = h @ w.astype(np.float64) + b.astype(np.float64)
        if i < len(params) - 1:
            h = np.maximum(h, 0.0)
    return h

probe = np.linspace(-1.0, 1.0, 8, dtype=np.float32)
logits = forward(probe)

PRED_W = np.array([3.2, 1.8, 0.9, -0.6])
PRED_B = -2.0
feats = [[0.9, 0.8, 0.7, 0.3], [0.0, 0.0, 0.0, 0.0]]
scores = [float(1.0 / (1.0 + np.exp(-(np.dot(f, PRED_W) + PRED_B)))) for f in feats]

manifest = {
    "generator": "python/tests fixture (make_fixture.py)",
    "input_dim": 8, "classes": 3, "hidden": [6],
    "batches": [1, 2], "predictor_batch": 4,
    "predictor_weights": PRED_W.tolist(), "predictor_bias": PRED_B,
    "artifacts": {},
    "check": {
        "classifier_input": "linspace(-1,1,8)",
        "classifier_logits_b1": [float(v) for v in logits],
        "predictor_feats": feats,
        "predictor_scores": scores,
    },
    "weights": {"format": "f32-le",
                 "normalize": {"mean": MEAN, "std": STD},
                 "layers": layers},
}
with open(os.path.join(out, "manifest.json"), "w") as f:
    json.dump(manifest, f, indent=2)
    f.write("\n")
print("logits:", logits)
print("scores:", scores)
print("files:", sorted(os.listdir(out)))
