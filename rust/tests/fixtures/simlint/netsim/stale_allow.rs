//! Fixture for S002: a suppression that matches nothing.

// simlint: allow(D002, there is no clock here any more)
pub fn quiet() {}
