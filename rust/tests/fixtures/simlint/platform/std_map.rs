//! Fixture for D001: std map in a determinism-sensitive path.

use std::collections::HashMap;

pub fn hot_pool() -> HashMap<u64, u64> {
    HashMap::new()
}
