//! Fixture for D007: a String-keyed map in an executor hot path.

use crate::util::fxhash::FxHashMap;

pub struct WarmPool {
    pub by_function: FxHashMap<String, Vec<u64>>,
}
