//! Fixture proving placement code sits INSIDE the determinism perimeter:
//! a placement strategy that stamps decisions with wall-clock time is a
//! D002 finding — `platform/placement*.rs` is in `SIM_PATHS`, not the
//! wall-clock allowlist.

pub fn decision_stamp() -> u64 {
    let now = std::time::SystemTime::now();
    now.duration_since(std::time::UNIX_EPOCH).unwrap().as_secs()
}
