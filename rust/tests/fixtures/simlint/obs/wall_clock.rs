//! Fixture proving `obs/` sits INSIDE the determinism perimeter: the
//! tracing subsystem observes sim time only, so a wall-clock read in an
//! obs path is a D002 finding (obs/ is deliberately not allowlisted).

pub fn span_stamp() -> u128 {
    let t0 = std::time::Instant::now();
    t0.elapsed().as_micros()
}
