//! Fixture for D005: unchecked `as` narrowing on a counter.

pub fn pack(count: u64) -> u32 {
    count as u32
}

pub fn widen(count: u32) -> u64 {
    count as u64
}
