//! Fixture for D004: hard-coded literal seed bypassing mix64/fork.

pub fn stream() -> u64 {
    let mut rng = Rng::new(42);
    rng.next_u64()
}

pub fn derived(seed: u64) -> u64 {
    let mut rng = Rng::new(mix64(seed, 7));
    rng.next_u64()
}
