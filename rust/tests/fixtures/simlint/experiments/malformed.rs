//! Fixture for S001: a directive missing its reason (and thus
//! suppressing nothing).

use std::collections::HashMap; // simlint: allow(D001)

pub fn m() -> Option<HashMap<u8, u8>> {
    None
}
