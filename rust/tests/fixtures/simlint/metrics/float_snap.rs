//! Fixture for D003: float field in a mergeable-metrics struct.

pub struct WindowMetrics {
    pub cold: u64,
    pub rate: f64,
}

pub struct Scratch {
    pub tmp: f64,
}
