//! Fixture for a justified, working suppression: lints clean.

// simlint: allow(D001, fixture exercises the suppression path; never drained)
use std::collections::HashMap;

pub type PoolId = u64;
