//! Fixture for D006: completion-order thread fan-out.

pub fn fan(jobs: Vec<u64>) {
    for j in jobs {
        std::thread::spawn(move || j + 1);
    }
}
