//! Fixture for D002: wall-clock reads outside the allowlist.

pub fn stamp() -> u128 {
    let t0 = std::time::Instant::now();
    t0.elapsed().as_nanos()
}

pub fn epoch() -> bool {
    std::time::SystemTime::now().elapsed().is_ok()
}
