//! Integration tests: the full platform on the discrete-event substrate.
//!
//! These exercise the paper's scenarios end to end: λ with and without
//! freshen (Figure 3's predicted and unanticipated timings), chain-driven
//! prediction through trigger services, staleness handling, billing, and
//! queueing/eviction behaviour.

use freshen_rs::netsim::link::Site;
use freshen_rs::platform::endpoint::Endpoint;
use freshen_rs::platform::exec::{self, invoke, start_freshen};
use freshen_rs::platform::function::{Arg, FunctionSpec, Op};
use freshen_rs::platform::world::{PlatformSim, World};
use freshen_rs::simcore::Sim;
use freshen_rs::triggers::TriggerService;
use freshen_rs::util::config::Config;
use freshen_rs::util::time::{SimDuration, SimTime};

/// Build a world with one remote store endpoint holding the λ objects.
fn world_with_store(site: Site) -> World {
    let mut cfg = Config::default();
    cfg.seed = 42;
    let mut w = World::new(cfg);
    let mut ep = Endpoint::new("store", site);
    ep.store.put("ID1", 5e6, SimTime::ZERO); // 5 MB model
    w.add_endpoint(ep);
    w
}

fn lambda(id: &str) -> FunctionSpec {
    FunctionSpec::paper_lambda(id, "app", "store", SimDuration::from_millis(20))
}

fn run_sim(w: &mut World, f: impl FnOnce(&mut PlatformSim, &mut World)) {
    let mut sim: PlatformSim = Sim::new();
    sim.max_events = 10_000_000;
    f(&mut sim, w);
    sim.run(w);
}

#[test]
fn single_invocation_completes_with_cold_start() {
    let mut w = world_with_store(Site::Remote);
    w.deploy(lambda("f"));
    run_sim(&mut w, |sim, w| {
        invoke(sim, w, "f");
    });
    assert_eq!(w.metrics.count(), 1);
    assert_eq!(w.metrics.cold_starts, 1);
    let rec = &w.metrics.records()[0];
    // Latency >= cold start (500ms) + fetch over 50ms WAN + compute.
    assert!(rec.latency() > SimDuration::from_millis(550), "{}", rec.latency());
    // The put landed in the store.
    assert!(w.endpoints["store"].store.peek("ID2").is_some());
    // Billing happened.
    assert!(w.ledger.account("app").exec_gb_s > 0.0);
    assert_eq!(w.ledger.account("app").invocations, 1);
}

#[test]
fn second_invocation_is_warm_and_faster() {
    let mut w = world_with_store(Site::Remote);
    w.deploy(lambda("f"));
    run_sim(&mut w, |sim, w| {
        invoke(sim, w, "f");
        sim.schedule(SimDuration::from_secs(2), |sim, w| {
            invoke(sim, w, "f");
        });
    });
    assert_eq!(w.metrics.count(), 2);
    assert_eq!(w.metrics.cold_starts, 1);
    assert_eq!(w.metrics.warm_starts, 1);
    let recs = w.metrics.records();
    assert!(recs[1].latency() < recs[0].latency());
}

#[test]
fn freshen_before_invocation_cuts_latency() {
    // Figure 3 (left): freshen completes before run; the function consumes
    // prefetched data and a warmed connection.
    let mut cold = world_with_store(Site::Remote);
    cold.deploy(lambda("f"));
    run_sim(&mut cold, |sim, w| {
        invoke(sim, w, "f");
        // second, warm invocation without freshen
        sim.schedule(SimDuration::from_secs(30), |sim, w| {
            invoke(sim, w, "f");
        });
    });
    let baseline = cold.metrics.records()[1].latency();

    let mut fresh = world_with_store(Site::Remote);
    fresh.deploy(lambda("f"));
    run_sim(&mut fresh, |sim, w| {
        invoke(sim, w, "f");
        // freshen fires 1s before the second invocation
        sim.schedule(SimDuration::from_secs(29), |sim, w| {
            start_freshen(sim, w, "f", None);
        });
        sim.schedule(SimDuration::from_secs(30), |sim, w| {
            invoke(sim, w, "f");
        });
    });
    let freshened = fresh.metrics.records()[1].latency();
    assert!(
        freshened < baseline,
        "freshened {freshened} should beat baseline {baseline}"
    );
    // The function consumed freshen results.
    assert!(fresh.metrics.records()[1].freshen_hits >= 1);
    assert_eq!(fresh.metrics.freshens_completed, 1);
}

#[test]
fn freshen_simultaneous_with_run_still_correct() {
    // Figure 3 (right): freshen and run race; wrappers must coordinate via
    // fr_state (FrWait) and the function must still complete correctly.
    let mut w = world_with_store(Site::Remote);
    w.deploy(lambda("f"));
    run_sim(&mut w, |sim, w| {
        invoke(sim, w, "f"); // cold start ~500ms
        sim.schedule(SimDuration::from_secs(5), |sim, w| {
            // Same instant: freshen + run.
            start_freshen(sim, w, "f", None);
            invoke(sim, w, "f");
        });
    });
    assert_eq!(w.metrics.count(), 2, "both invocations completed");
    let rec = &w.metrics.records()[1];
    // All resources were handled exactly once (no double-fetch): the put
    // object exists, and hits+misses == resource count.
    assert_eq!(rec.freshen_hits + rec.freshen_misses, 2);
    assert!(w.endpoints["store"].store.peek("ID2").is_some());
}

#[test]
fn chain_invocation_triggers_freshen_on_successor() {
    let mut w = world_with_store(Site::Remote);
    let mut first = lambda("first");
    first.ops.push(Op::InvokeNext {
        function: "second".into(),
        trigger: TriggerService::Direct,
    });
    w.deploy(first);
    w.deploy(lambda("second"));
    // Warm up both containers so the chain effect isolates freshen.
    run_sim(&mut w, |sim, w| {
        invoke(sim, w, "second");
        sim.schedule(SimDuration::from_secs(5), |sim, w| {
            invoke(sim, w, "first");
        });
    });
    // first ran once; second ran twice (warmup + chained).
    assert_eq!(w.metrics.count(), 3);
    // The chain prediction admitted a freshen for `second`.
    assert!(w.metrics.freshens_started >= 1, "chain prediction freshened");
    assert!(w.tracker.hits >= 1, "prediction confirmed by arrival");
    // The chained `second` invocation benefited.
    let chained = w
        .metrics
        .records()
        .iter()
        .filter(|r| r.function == "second")
        .last()
        .unwrap();
    assert!(chained.freshen_hits >= 1, "successor consumed freshen results");
}

#[test]
fn stale_prefetch_is_refetched_strict_versions() {
    let mut w = world_with_store(Site::Remote);
    w.strict_versions = true;
    w.deploy(lambda("f"));
    run_sim(&mut w, |sim, w| {
        invoke(sim, w, "f"); // warms container, caches ID1@v1
        sim.schedule(SimDuration::from_secs(2), |sim, w| {
            start_freshen(sim, w, "f", None); // prefetches ID1@v1
        });
        // External writer bumps the object to v2 after the prefetch.
        sim.schedule(SimDuration::from_secs(4), |sim, w| {
            let now = sim.now();
            w.endpoints.get_mut("store").unwrap().store.external_update("ID1", 5e6, now);
        });
        sim.schedule(SimDuration::from_secs(5), |sim, w| {
            invoke(sim, w, "f");
        });
    });
    // The second invocation must NOT have used the stale v1 prefetch for
    // its DataGet; it refetched (so that resource was a freshen miss).
    let rec = w.metrics.records().last().unwrap();
    assert!(rec.freshen_misses >= 1, "stale data must be refetched");
}

#[test]
fn queueing_when_cluster_full() {
    let mut cfg = Config::default();
    cfg.invokers = 1;
    cfg.containers_per_invoker = 1;
    cfg.seed = 1;
    let mut w = World::new(cfg);
    let mut ep = Endpoint::new("store", Site::Edge);
    ep.store.put("ID1", 1e4, SimTime::ZERO);
    w.add_endpoint(ep);
    w.deploy(lambda("f"));
    w.deploy(lambda("g"));
    run_sim(&mut w, |sim, w| {
        invoke(sim, w, "f");
        invoke(sim, w, "g"); // no slot: queued until f's container... never freed for g
        sim.schedule(SimDuration::from_secs(700), |_sim, _w| {}); // let eviction fire
    });
    // g eventually ran: f's container idles out after idle_eviction (600s),
    // freeing the slot — but our queue drain is per-function, so g's
    // dispatch happens through the eviction path. Check both completed.
    assert_eq!(w.metrics.count(), 2, "both invocations completed");
    assert!(w.metrics.evictions >= 1);
}

#[test]
fn deterministic_given_seed() {
    let run = || {
        let mut w = world_with_store(Site::Remote);
        w.deploy(lambda("f"));
        run_sim(&mut w, |sim, w| {
            for i in 0..10u64 {
                sim.schedule(SimDuration::from_secs(i * 3), |sim, w| {
                    invoke(sim, w, "f");
                });
            }
        });
        w.metrics
            .records()
            .iter()
            .map(|r| r.latency().micros())
            .collect::<Vec<u64>>()
    };
    assert_eq!(run(), run());
}

#[test]
fn billing_attributes_freshen_to_app_owner() {
    let mut w = world_with_store(Site::Remote);
    w.deploy(lambda("f"));
    run_sim(&mut w, |sim, w| {
        invoke(sim, w, "f");
        // Past the prefetch TTL (10s default), so the freshen hook has real
        // work to do (a zero-duration skip would bill zero GB-seconds).
        sim.schedule(SimDuration::from_secs(20), |sim, w| {
            start_freshen(sim, w, "f", None); // developer-invoked: bills now
        });
    });
    let acct = w.ledger.account("app");
    assert!(acct.freshen_useful_gb_s > 0.0, "owner pays for freshen");
    assert!(acct.network_bytes > 0.0);
}

#[test]
fn ensure_connection_is_idempotent_for_live_conn() {
    // Directly exercise the helper: second ensure on a live connection
    // costs only a keepalive RTT, not a handshake.
    let mut w = world_with_store(Site::Remote);
    w.deploy(lambda("f"));
    // Remove RTT jitter so the comparison is exact: establish = RTT +
    // endpoint overhead, keepalive = RTT only.
    w.endpoints.get_mut("store").unwrap().link.jitter_sigma = 0.0;
    let mut env = freshen_rs::platform::container::RuntimeEnv::new();
    let t0 = SimTime::ZERO;
    let d1 = exec::ensure_connection(&mut w.endpoints, &mut w.rng, &mut env, "store", t0);
    let t1 = t0 + d1 + SimDuration::from_secs(1);
    let d2 = exec::ensure_connection(&mut w.endpoints, &mut w.rng, &mut env, "store", t1);
    assert!(d2 < d1, "keepalive {d2} should be cheaper than establish {d1}");
    assert_eq!(env.connections["store"].establish_count, 1);
}

// ====================================================================
// Extensions: branching chains, isolation scopes, failure injection
// ====================================================================

#[test]
fn branching_chain_learns_edge_probabilities() {
    // §6 non-deterministic chains: a 0.85/0.15 branch. The predictor's
    // edge confidence converges to the observed frequencies, so the hot
    // branch keeps being freshened and the cold one gets gated out.
    let mut w = world_with_store(Site::Remote);
    w.gate.config.min_confidence = 0.5;
    let mut head = lambda("head");
    head.ops.push(Op::InvokeBranch {
        branches: vec![("hot".into(), 0.85), ("cold".into(), 0.15)],
        trigger: TriggerService::Direct,
    });
    w.deploy(head);
    w.deploy(lambda("hot"));
    w.deploy(lambda("cold"));
    run_sim(&mut w, |sim, w| {
        for i in 0..40u64 {
            sim.schedule(SimDuration::from_secs(5 + i * 20), |sim, w| {
                invoke(sim, w, "head");
            });
        }
    });
    let hot_conf = w.chain_pred.edge_confidence("head", "hot");
    let cold_conf = w.chain_pred.edge_confidence("head", "cold");
    assert!(hot_conf > 0.6, "hot edge confidence {hot_conf}");
    assert!(cold_conf < 0.5, "cold edge confidence {cold_conf}");
    assert!(hot_conf > cold_conf + 0.3);
    // Both targets actually ran at least once (or hot did, at minimum).
    let hot_runs = w.metrics.records().iter().filter(|r| r.function == "hot").count();
    assert!(hot_runs >= 20, "hot ran {hot_runs} times");
}

#[test]
fn per_app_isolation_reinits_instead_of_cold_starting() {
    use freshen_rs::util::config::IsolationScope;
    let run_with = |isolation: IsolationScope| {
        let mut cfg = Config::default();
        cfg.seed = 11;
        cfg.isolation = isolation;
        cfg.invokers = 1;
        cfg.containers_per_invoker = 1; // one slot: sharing is forced
        let mut w = World::new(cfg);
        let mut ep = Endpoint::new("store", Site::Remote);
        ep.store.put("ID1", 1e6, SimTime::ZERO);
        w.add_endpoint(ep);
        w.deploy(lambda("alpha")); // same app ("app") for both
        w.deploy(lambda("beta"));
        let mut sim: PlatformSim = Sim::new();
        sim.max_events = 10_000_000;
        invoke(&mut sim, &mut w, "alpha");
        sim.schedule(SimDuration::from_secs(5), |sim, w| {
            invoke(sim, w, "beta");
        });
        sim.run(&mut w);
        w
    };
    let per_app = run_with(IsolationScope::PerApp);
    assert_eq!(per_app.metrics.count(), 2, "both ran");
    assert_eq!(per_app.metrics.cold_starts, 1, "beta re-inited, not cold");
    assert_eq!(per_app.metrics.reinits, 1);
    // The shared runtime kept alpha's warmed connection: beta's latency
    // beats the per-function case, where beta queues for the single slot.
    let per_fn = run_with(IsolationScope::PerFunction);
    let beta_app = per_app.metrics.records().iter().find(|r| r.function == "beta").unwrap();
    let beta_fn = per_fn.metrics.records().iter().find(|r| r.function == "beta");
    match beta_fn {
        Some(rec) => assert!(beta_app.latency() < rec.latency()),
        None => {} // per-function: beta still queued at sim end
    }
}

#[test]
fn unknown_endpoint_is_not_fatal() {
    // Failure injection: a function whose endpoint was never registered
    // must still complete (fetches fail fast; freshen inference emits a
    // hook whose actions no-op).
    let mut w = world_with_store(Site::Remote);
    w.deploy(FunctionSpec::paper_lambda(
        "ghost-ep",
        "app",
        "no-such-endpoint",
        SimDuration::from_millis(5),
    ));
    run_sim(&mut w, |sim, w| {
        invoke(sim, w, "ghost-ep");
        sim.schedule(SimDuration::from_secs(2), |sim, w| {
            start_freshen(sim, w, "ghost-ep", None);
        });
        sim.schedule(SimDuration::from_secs(4), |sim, w| {
            invoke(sim, w, "ghost-ep");
        });
    });
    assert_eq!(w.metrics.count(), 2, "completes despite missing endpoint");
}

#[test]
fn missing_object_fetch_fails_gracefully() {
    let mut w = world_with_store(Site::Remote);
    let f = FunctionSpec::new(
        "fetch-missing",
        "app",
        vec![Op::DataGet {
            endpoint: "store".into(),
            creds: Arg::Const("CREDS".into()),
            object_id: Arg::Const("DOES-NOT-EXIST".into()),
        }],
    );
    w.deploy(f);
    run_sim(&mut w, |sim, w| {
        // Freshen first: its prefetch fails (404) — "failure to infer is
        // not fatal" extends to failure to freshen.
        start_freshen(sim, w, "fetch-missing", None);
        sim.schedule(SimDuration::from_secs(3), |sim, w| {
            invoke(sim, w, "fetch-missing");
        });
    });
    assert_eq!(w.metrics.count(), 1);
    // The wrapper redid the (failing) fetch itself: a freshen miss.
    assert!(w.metrics.records()[0].freshen_misses >= 1);
}

#[test]
fn lossy_link_reduces_but_keeps_warming_benefit() {
    use freshen_rs::netsim::cc::CongestionControl;
    use freshen_rs::netsim::tcp::Connection;
    use freshen_rs::util::rng::Rng;
    let mut lossless = Site::Remote.link();
    lossless.jitter_sigma = 0.0;
    let lossy = lossless.clone().with_loss(0.10);
    let send = |link: &freshen_rs::netsim::link::Link, warm: bool, seed: u64| {
        let mut rng = Rng::new(seed);
        let mut c = Connection::new(link.clone(), CongestionControl::Cubic);
        let mut t = SimTime::ZERO + c.connect(SimTime::ZERO, &mut rng);
        if warm {
            t = t + c.send_with_ack(t, &mut rng, 2e7, 0.0);
        }
        c.send_with_ack(t, &mut rng, 1e7, 0.0).as_secs_f64()
    };
    // Average over seeds (loss is stochastic per round).
    let avg = |link: &freshen_rs::netsim::link::Link, warm: bool| -> f64 {
        (0..30).map(|s| send(link, warm, s)).sum::<f64>() / 30.0
    };
    // Loss makes transfers slower on average...
    assert!(avg(&lossy, false) > avg(&lossless, false));
    // ...and erodes the warming advantage: on a heavily lossy path the
    // congestion controller claws back whatever warm_cwnd granted, so the
    // benefit must be strictly smaller than on the clean path (it can even
    // go negative — warmed connections sit in congestion avoidance while
    // fresh ones slow-start). This bounds when freshen warming is useful.
    let benefit_clean = 1.0 - avg(&lossless, true) / avg(&lossless, false);
    let benefit_lossy = 1.0 - avg(&lossy, true) / avg(&lossy, false);
    assert!(benefit_clean > 0.4, "clean warming benefit {benefit_clean}");
    assert!(
        benefit_lossy < benefit_clean - 0.1,
        "lossy {benefit_lossy} vs clean {benefit_clean}"
    );
}

#[test]
fn variability_quantified_with_freshen() {
    // §6: "Quantifying how freshen affects variability in application
    // behavior would be an important component of this evaluation."
    // Measured finding: freshen shrinks latency in *absolute* terms at
    // both the body and the tail; the *relative* dispersion (CV) can rise
    // because the body collapses faster than the tail. Assert the
    // absolute improvements and that the CV stays in a sane band.
    let e = freshen_rs::experiments::e2e::run(0xFA12, 40);
    assert!(
        e.freshened.all_latency.p50 < e.baseline.all_latency.p50,
        "p50 {} vs {}",
        e.freshened.all_latency.p50,
        e.baseline.all_latency.p50
    );
    assert!(
        e.freshened.all_latency.p99 <= e.baseline.all_latency.p99 * 1.05,
        "p99 {} vs {}",
        e.freshened.all_latency.p99,
        e.baseline.all_latency.p99
    );
    assert!(e.freshened.latency_cv() < 3.0, "CV {}", e.freshened.latency_cv());
}
