//! Integration tests for the `simlint` determinism pass (`repro lint`).
//!
//! Three layers: (1) the on-disk fixture corpus under
//! `tests/fixtures/simlint/` — one dirty file per rule, arranged in scoped
//! subdirectories so path scoping applies exactly as it does over
//! `rust/src` — is linted via `lint_tree` and must produce the expected
//! findings; (2) per-rule source fixtures via `lint_source` pin the scope
//! boundaries and suppression semantics; (3) the self-clean gate: the
//! crate's own sources lint to zero findings, which is the invariant CI
//! enforces.

use std::path::Path;

use freshen_rs::analysis::{lint_source, lint_tree, rules};

fn fixture_root() -> &'static Path {
    Path::new(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/fixtures/simlint"
    ))
}

#[test]
fn fixture_corpus_produces_expected_findings() {
    let (findings, files) = lint_tree(fixture_root()).expect("fixture corpus lints");
    assert_eq!(files, 12, "fixture corpus file count");

    let count = |rule: &str| findings.iter().filter(|f| f.rule == rule).count();
    assert_eq!(count("D001"), 5, "{findings:?}");
    assert_eq!(count("D002"), 4, "{findings:?}");
    assert_eq!(count("D003"), 1, "{findings:?}");
    assert_eq!(count("D004"), 1, "{findings:?}");
    assert_eq!(count("D005"), 1, "{findings:?}");
    assert_eq!(count("D006"), 1, "{findings:?}");
    assert_eq!(count("D007"), 1, "{findings:?}");
    assert_eq!(count("S001"), 1, "{findings:?}");
    assert_eq!(count("S002"), 1, "{findings:?}");
    assert_eq!(findings.len(), 16, "no unexpected findings");

    // The obs/ fixture pins tracing inside the perimeter: its wall-clock
    // read is a finding, not an allowlisted path.
    assert!(findings
        .iter()
        .any(|f| f.path == "obs/wall_clock.rs" && f.rule == "D002"));

    // Placement code is inside the perimeter too: a wall-clock read in a
    // platform placement file is a D002 finding, not allowlisted.
    assert!(findings
        .iter()
        .any(|f| f.path == "platform/placement_wall_clock.rs" && f.rule == "D002"));

    // Findings carry root-relative `/`-separated paths and stable ordering.
    assert!(findings.iter().all(|f| !f.path.contains('\\')));
    let mut sorted = findings.iter().map(|f| (&f.path, f.line, f.rule)).collect::<Vec<_>>();
    sorted.sort();
    assert_eq!(
        sorted,
        findings.iter().map(|f| (&f.path, f.line, f.rule)).collect::<Vec<_>>()
    );

    // The clean fixture (a used, justified allow) contributes nothing.
    assert!(findings.iter().all(|f| f.path != "freshen/suppressed.rs"));
    // The malformed directive is reported AND fails to suppress.
    assert!(findings
        .iter()
        .any(|f| f.path == "experiments/malformed.rs" && f.rule == "S001"));
    assert!(findings
        .iter()
        .any(|f| f.path == "experiments/malformed.rs" && f.rule == "D001" && f.line == 4));
}

#[test]
fn d001_scope_boundaries() {
    let src = "fn f() { let m: HashMap<u32, u32> = HashMap::new(); }";
    assert_eq!(lint_source("platform/world.rs", src).len(), 2);
    assert_eq!(lint_source("metrics/mod.rs", src).len(), 2);
    assert!(lint_source("util/fxhash.rs", src).is_empty());
    assert!(lint_source("cli/mod.rs", src).is_empty());
    assert!(lint_source("analysis/rules.rs", src).is_empty());
}

#[test]
fn d002_wall_clock_allowlist() {
    let src = "fn f() { let t0 = Instant::now(); }";
    assert_eq!(lint_source("netsim/tcp.rs", src).len(), 1);
    assert_eq!(lint_source("obs/span.rs", src).len(), 1, "obs/ is sim-time-only");
    assert!(lint_source("serve/engine.rs", src).is_empty());
    assert!(lint_source("runtime/host.rs", src).is_empty());
    assert!(lint_source("testkit/bench.rs", src).is_empty());
}

#[test]
fn d003_only_flags_mergeable_struct_floats() {
    let merge = "struct ShardMetrics { warm: u64, ratio: f64 }";
    let scratch = "struct Planner { ratio: f64 }";
    assert_eq!(lint_source("metrics/hist.rs", merge).len(), 1);
    assert!(lint_source("metrics/hist.rs", scratch).is_empty());
    // Out of the merged-metrics scope entirely.
    assert!(lint_source("netsim/cc.rs", merge).is_empty());
}

#[test]
fn d004_flags_literal_seeds_not_derived_ones() {
    assert_eq!(
        lint_source("predict/chain.rs", "fn f() { let r = Rng::new(0xBEEF); }").len(),
        1
    );
    assert!(lint_source(
        "predict/chain.rs",
        "fn f(s: u64) { let r = Rng::new(mix64(s, 1)); let q = r.fork(2); }"
    )
    .is_empty());
}

#[test]
fn d005_narrowing_casts_in_counter_paths() {
    let src = "fn f(x: u64) -> u32 { x as u32 }";
    assert_eq!(lint_source("workload/azure.rs", src).len(), 1);
    assert!(lint_source("simcore/wheel.rs", src).is_empty());
    assert!(lint_source("workload/azure.rs", "fn f(x: u32) -> u64 { x as u64 }").is_empty());
}

#[test]
fn d006_thread_fanout_outside_exempt_paths() {
    let src = "fn f() { std::thread::scope(|s| {}); }";
    assert_eq!(lint_source("platform/world.rs", src).len(), 1);
    assert!(lint_source("serve/pool.rs", src).is_empty());
    assert!(lint_source("testkit/harness.rs", src).is_empty());
    // Non-fan-out thread APIs never match.
    assert!(lint_source(
        "platform/world.rs",
        "fn f() { let n = std::thread::available_parallelism(); }"
    )
    .is_empty());
}

#[test]
fn d007_string_keys_in_hot_paths_only() {
    let src = "struct Pool { warm: FxHashMap<String, u64> }";
    assert_eq!(lint_source("platform/keepalive.rs", src).len(), 1);
    assert_eq!(lint_source("simcore/waitlist.rs", src).len(), 1);
    // Deploy/ingest boundaries and non-hot subsystems keep String keys.
    assert!(lint_source("platform/datastore.rs", src).is_empty());
    assert!(lint_source("platform/endpoint.rs", src).is_empty());
    assert!(lint_source("predict/hist.rs", src).is_empty());
    assert!(lint_source("cli/mod.rs", src).is_empty());
    // FnId-keyed maps are the sanctioned replacement.
    assert!(lint_source(
        "platform/keepalive.rs",
        "struct Pool { warm: FxHashMap<FnId, u64> }"
    )
    .is_empty());
}

#[test]
fn suppression_covers_same_and_next_line_only() {
    let hit_then_clean = "\
// simlint: allow(D001, pinned digest exercises this map)
use std::collections::HashMap;
fn f() -> HashMap<u8, u8> { HashMap::new() }";
    let out = lint_source("platform/x.rs", hit_then_clean);
    // Line 2 suppressed; line 3 has two unsuppressed hits.
    assert_eq!(out.iter().filter(|f| f.rule == "D001").count(), 2);
    assert!(out.iter().all(|f| f.line == 3));
    // No S002: the directive was used.
    assert!(out.iter().all(|f| f.rule != "S002"));
}

#[test]
fn multi_rule_directive_suppresses_both() {
    let src = "// simlint: allow(D001 D004, replay pinned; seed is a doc example)\n\
               fn f() { let m = HashMap::new(); let r = Rng::new(1); }";
    assert!(lint_source("platform/x.rs", src).is_empty());
}

#[test]
fn cfg_test_code_is_not_linted() {
    let src = "\
#[cfg(test)]
mod tests {
    use std::collections::HashMap;
    fn t() { let r = Rng::new(7); let x = 3u64 as u32; }
}";
    assert!(lint_source("metrics/mod.rs", src).is_empty());
}

#[test]
fn catalog_is_complete_and_ordered() {
    let ids: Vec<&str> = rules::CATALOG.iter().map(|r| r.id).collect();
    assert_eq!(
        ids,
        vec!["D001", "D002", "D003", "D004", "D005", "D006", "D007", "S001", "S002"]
    );
    for r in rules::CATALOG {
        assert!(!r.summary.is_empty() && !r.hint.is_empty(), "{} lacks docs", r.id);
    }
}

#[test]
fn crate_sources_lint_clean() {
    // The gate CI enforces via `repro lint`: the crate's own sources carry
    // zero findings — every true positive is fixed or carries an audited
    // allow, and no allow is stale.
    let src_root = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/src"));
    let (findings, files) = lint_tree(src_root).expect("crate sources lint");
    assert!(files > 50, "walked the real tree, not a stub ({files} files)");
    let rendered: Vec<String> = findings.iter().map(ToString::to_string).collect();
    assert!(findings.is_empty(), "simlint findings:\n{}", rendered.join("\n"));
}
