//! Determinism regression tests for the `obs/` tracing subsystem.
//!
//! The acceptance contract mirrors `azure_macro_determinism.rs`, extended
//! to the span stream itself:
//!
//! - **spans-on invariance**: with tracing enabled, the merged span
//!   stream's digest is byte-identical across `--shards 1/2/8` ×
//!   `--parallel 1/4` in per-app pool mode — the same grid the metrics
//!   digest already pins.
//! - **spans-off identity**: enabling tracing never moves the metrics
//!   digest; disabling it never leaves residue. The default (spans off)
//!   is byte-identical to a pre-obs build.
//! - **export round-trip**: the Chrome trace_event export parses as one
//!   JSON document with monotone, non-negative timestamps, and both
//!   export formats summarize identically.

use freshen_rs::experiments::azure_macro::{run_multi, AzureMacroCfg, Mitigation, Variant};
use freshen_rs::experiments::SweepRunner;
use freshen_rs::obs::{summarize, to_chrome, to_jsonl, SpanKind};
use freshen_rs::util::json::Json;
use freshen_rs::workload::macrotrace::replay::PoolMode;
use freshen_rs::workload::macrotrace::shard::TraceSource;
use freshen_rs::workload::macrotrace::synth::SynthTraceCfg;

fn trace() -> SynthTraceCfg {
    SynthTraceCfg {
        apps: 40,
        minutes: 20,
        seed: 99,
        ..SynthTraceCfg::default()
    }
}

fn cfg(shards: usize, spans: bool) -> AzureMacroCfg {
    let mut cfg = AzureMacroCfg::new(TraceSource::Synth(trace()));
    cfg.shards = shards;
    cfg.warmup_minutes = 4;
    cfg.variants = vec![Variant::Baseline, Variant::Both];
    cfg.trace_spans = spans;
    cfg
}

#[test]
fn span_streams_are_byte_identical_across_shards_and_parallelism() {
    let seeds = [7u64];
    let reference = run_multi(&cfg(1, true), &seeds, &SweepRunner::new(1)).expect("reference");
    let ref_spans = reference.span_digest();
    let total: usize = reference.rows.iter().map(|r| r.metrics.spans.len()).sum();
    assert!(total > 1000, "tracing must actually record spans ({total})");
    assert!(ref_spans.contains("n="), "span digest carries counts: {ref_spans}");
    for shards in [1usize, 2, 8] {
        for parallel in [1usize, 4] {
            let r = run_multi(&cfg(shards, true), &seeds, &SweepRunner::new(parallel))
                .expect("sharded run");
            assert_eq!(
                ref_spans,
                r.span_digest(),
                "span stream diverged at shards={shards} parallel={parallel}"
            );
            assert_eq!(
                reference.digest(),
                r.digest(),
                "metrics diverged at shards={shards} parallel={parallel}"
            );
        }
    }
}

#[test]
fn tracing_and_windows_never_perturb_the_metrics_digest() {
    let seeds = [7u64];
    let off = run_multi(&cfg(2, false), &seeds, &SweepRunner::new(2)).unwrap();
    let mut on_cfg = cfg(2, true);
    on_cfg.fn_windows = true;
    let on = run_multi(&on_cfg, &seeds, &SweepRunner::new(2)).unwrap();
    assert_eq!(
        off.digest(),
        on.digest(),
        "span/window collection must be invisible to the digest contract"
    );
    // Off really is off: no spans, no windows, zero residue.
    for row in &off.rows {
        assert!(row.metrics.spans.is_empty());
        assert_eq!(row.metrics.spans.dropped, 0);
        assert!(row.metrics.fn_windows.is_empty());
    }
    // On really is on, for every cell.
    for row in &on.rows {
        assert!(!row.metrics.spans.is_empty(), "{:?} recorded no spans", row.variant);
        assert!(!row.metrics.fn_windows.is_empty(), "{:?} has no windows", row.variant);
    }
}

#[test]
fn shared_pool_spans_are_parallel_invariant() {
    let mut c = cfg(2, true);
    c.pool = PoolMode::Shared;
    let serial = run_multi(&c, &[7], &SweepRunner::new(1)).unwrap();
    let parallel = run_multi(&c, &[7], &SweepRunner::new(4)).unwrap();
    assert_eq!(serial.span_digest(), parallel.span_digest());
    // Shared pools qualify function names `app/function`, so a span
    // stream from a shared world names its tenant on every event.
    let rows = serial.span_rows();
    let (_, sink) = &rows[0];
    let (_, events) = &sink.groups()[0];
    assert!(events.iter().all(|e| e.function.contains('/')));
}

#[test]
fn span_filter_selects_a_tenant() {
    // Grab one app's name from an unfiltered run, then filter on it.
    let full = run_multi(&cfg(2, true), &[7], &SweepRunner::new(2)).unwrap();
    let needle = {
        let rows = full.span_rows();
        let (group, _) = &rows[0].1.groups()[0];
        group.clone()
    };
    let mut c = cfg(2, true);
    c.span_filter = Some(needle.clone());
    let filtered = run_multi(&c, &[7], &SweepRunner::new(2)).unwrap();
    let rows = filtered.span_rows();
    let total: usize = rows.iter().map(|(_, s)| s.len()).sum();
    assert!(total > 0, "filter '{needle}' matched nothing");
    for (_, sink) in &rows {
        for (_, events) in sink.groups() {
            assert!(
                events.iter().all(|e| e.function.contains(&needle)),
                "a span escaped the '{needle}' filter"
            );
        }
    }
    let full_total: usize = full.span_rows().iter().map(|(_, s)| s.len()).sum();
    assert!(total < full_total, "the filter must actually narrow the stream");
    // Filter misses are a deliberate exclusion, NOT ring overflow: they
    // land in the separate `filtered` tally, and the unfiltered run
    // filters nothing. kept + filtered + overflowed partitions the same
    // underlying event stream in both runs (the filter never changes sim
    // behavior, only what the ring keeps).
    let filtered_total: u64 = rows.iter().map(|(_, s)| s.filtered).sum();
    assert!(filtered_total > 0, "the narrowed run must count its filter misses");
    let filt_dropped: u64 = rows.iter().map(|(_, s)| s.dropped).sum();
    let full_rows = full.span_rows();
    let full_filtered: u64 = full_rows.iter().map(|(_, s)| s.filtered).sum();
    let full_dropped: u64 = full_rows.iter().map(|(_, s)| s.dropped).sum();
    assert_eq!(full_filtered, 0, "no filter, no filter misses");
    assert_eq!(
        total as u64 + filtered_total + filt_dropped,
        full_total as u64 + full_dropped,
        "kept + filtered + overflowed must partition the event stream"
    );
}

#[test]
fn snapshot_mitigation_emits_snapshot_spans() {
    let mut c = cfg(1, true);
    c.variants = vec![Variant::Baseline];
    c.mitigations = Some(vec![Mitigation::Snapshot]);
    let r = run_multi(&c, &[7], &SweepRunner::new(1)).unwrap();
    let rows = r.span_rows();
    let creates: usize = rows
        .iter()
        .map(|(_, sink)| {
            sink.groups()
                .iter()
                .map(|(_, events)| {
                    events
                        .iter()
                        .filter(|e| e.kind == SpanKind::SnapshotCreate)
                        .count()
                })
                .sum::<usize>()
        })
        .sum();
    assert!(creates > 0, "demotions must be visible in the span stream");
    let total_snapshots: u64 = r.rows.iter().map(|row| row.metrics.snapshots).sum();
    assert_eq!(
        creates as u64, total_snapshots,
        "one snapshot_create span per counted demotion"
    );
}

#[test]
fn chrome_export_round_trips_with_monotone_timestamps() {
    let r = run_multi(&cfg(2, true), &[7], &SweepRunner::new(2)).unwrap();
    let rows = r.span_rows();
    let chrome = to_chrome(&rows);
    let doc = Json::parse(&chrome).expect("chrome export is valid JSON");
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents array");
    assert!(!events.is_empty());
    let mut slices = 0usize;
    let mut last_ts = 0u64;
    for e in events {
        match e.get("ph").and_then(Json::as_str) {
            Some("M") => continue, // process/thread metadata
            Some("X") => {}
            other => panic!("unexpected phase {other:?}"),
        }
        let ts = e.get("ts").and_then(Json::as_u64).expect("non-negative integer ts");
        e.get("dur").and_then(Json::as_u64).expect("non-negative integer dur");
        assert!(ts >= last_ts, "slices must be time-sorted ({ts} < {last_ts})");
        last_ts = ts;
        // Every slice names a known span kind.
        let name = e.get("name").and_then(Json::as_str).unwrap();
        assert!(SpanKind::parse(name).is_some(), "unknown kind '{name}'");
        slices += 1;
    }
    let total: usize = rows.iter().map(|(_, s)| s.len()).sum();
    assert_eq!(slices, total, "every recorded span becomes exactly one slice");
    // Byte-stable: exporting the same run twice gives identical text.
    assert_eq!(chrome, to_chrome(&rows));
}

#[test]
fn both_export_formats_summarize_identically() {
    let r = run_multi(&cfg(1, true), &[7], &SweepRunner::new(1)).unwrap();
    let rows = r.span_rows();
    let jsonl = to_jsonl(&rows);
    let chrome = to_chrome(&rows);
    // Every JSONL line is one standalone JSON object.
    for line in jsonl.lines() {
        Json::parse(line).expect("JSONL line parses");
    }
    let a = summarize(&jsonl).expect("jsonl summary");
    let b = summarize(&chrome).expect("chrome summary");
    assert_eq!(a, b, "the summarizer must not care about the wire format");
    assert!(a.starts_with("span summary:"), "summary header: {a}");
    // Garbage is rejected, emptiness is not.
    assert!(summarize("not json").is_err());
    assert!(summarize("").is_ok());
}

#[test]
fn fn_windows_track_real_activity() {
    let mut c = cfg(2, false);
    c.fn_windows = true;
    c.variants = vec![Variant::Both];
    let r = run_multi(&c, &[7], &SweepRunner::new(2)).unwrap();
    let w = &r.rows[0].metrics.fn_windows;
    assert!(w.len() > 10, "windows cover the trace's functions ({})", w.len());
    let top = w.top_by_invocations(5);
    assert!(!top.is_empty());
    // Ordered by volume, and internally consistent.
    for pair in top.windows(2) {
        assert!(pair[0].1.invocations >= pair[1].1.invocations);
    }
    let total_inv: u64 = w.top_by_invocations(usize::MAX)
        .iter()
        .map(|(_, fw)| fw.invocations)
        .sum();
    assert!(
        total_inv >= r.rows[0].metrics.invocations,
        "windows see at least the post-warmup invocation volume \
         ({total_inv} vs {})",
        r.rows[0].metrics.invocations
    );
    for (f, fw) in &top {
        assert!(fw.cold_per_mille() <= 1000, "{f} cold rate out of range");
        assert!(fw.windows > 0, "{f} closed no windows");
        assert!(fw.peak_window_invocations <= fw.invocations);
    }
}
