//! Hot-path overhaul regression tests: interned function ids, the
//! invocation slab, and enum-coded platform events.
//!
//! Three contracts from the hot-path PR:
//!
//! - **Symbol round-trip**: deploying interns each function name; `lookup`
//!   → `resolve` returns the same bytes, interning is idempotent (the same
//!   `FnId` comes back), and distinct names get distinct ids.
//! - **Slab bookkeeping**: the invocation slab's arrival counter matches
//!   the metrics hub, and the default (non-recycling) mode keeps one slot
//!   per arrival so handles minted mid-run can never dangle.
//! - **Enum/closure equivalence**: the enum-coded platform events must be
//!   behaviourally identical to the legacy boxed-closure encoding.
//!   `Sim::force_closures` routes every enum event through the
//!   `from_closure` escape hatch at schedule time, so the two runs differ
//!   ONLY in event representation — the full record stream (per-invocation
//!   timestamps included) must not move by a microsecond.

use freshen_rs::netsim::link::Site;
use freshen_rs::platform::endpoint::Endpoint;
use freshen_rs::platform::exec::{invoke, start_freshen};
use freshen_rs::platform::function::{FunctionSpec, Op};
use freshen_rs::platform::world::{PlatformSim, World};
use freshen_rs::simcore::Sim;
use freshen_rs::triggers::TriggerService;
use freshen_rs::util::config::Config;
use freshen_rs::util::time::{SimDuration, SimTime};

fn world_with_store() -> World {
    let mut cfg = Config::default();
    cfg.seed = 42;
    let mut w = World::new(cfg);
    let mut ep = Endpoint::new("store", Site::Remote);
    ep.store.put("ID1", 5e6, SimTime::ZERO);
    w.add_endpoint(ep);
    w
}

fn lambda(id: &str) -> FunctionSpec {
    FunctionSpec::paper_lambda(id, "app", "store", SimDuration::from_millis(20))
}

#[test]
fn deploy_interns_names_and_round_trips() {
    let mut w = world_with_store();
    for name in ["alpha", "beta", "gamma"] {
        w.deploy(lambda(name));
    }
    for name in ["alpha", "beta", "gamma"] {
        let id = w.registry.symbols.lookup(name).expect("deployed name is interned");
        assert_eq!(w.registry.symbols.resolve(id), name, "resolve returns the bytes back");
        assert_eq!(w.registry.symbols.intern(name), id, "re-interning is idempotent");
    }
    let a = w.registry.symbols.lookup("alpha").unwrap();
    let b = w.registry.symbols.lookup("beta").unwrap();
    assert_ne!(a, b, "distinct names get distinct ids");
    assert!(w.registry.symbols.lookup("never-deployed").is_none());
}

#[test]
fn slab_arrival_count_matches_metrics_without_recycling() {
    let mut w = world_with_store();
    w.deploy(lambda("f"));
    let mut sim: PlatformSim = Sim::new();
    sim.max_events = 10_000_000;
    for i in 0..10u64 {
        sim.schedule(SimDuration::from_secs(i * 3), |sim, w| {
            invoke(sim, w, "f");
        });
    }
    sim.run(&mut w);
    assert_eq!(w.metrics.count(), 10, "all arrivals completed");
    assert_eq!(w.invocations.total(), 10, "one slab insert per arrival");
    // Interactive runs keep recycling OFF: every context gets a fresh
    // slot, so a handle minted mid-run stays valid for the world's life
    // (replay opts in to recycling explicitly, where residency matters).
    assert_eq!(w.invocations.slots_allocated(), 10);
    assert_eq!(w.invocations.live(), 10);
    assert_eq!(
        w.invocations.iter().filter(|c| c.done).count(),
        w.metrics.count(),
        "slab completion flags agree with the metrics hub"
    );
}

/// Drive a chained workload (cold starts, warm hits, chain predictions,
/// a developer freshen) once with enum-coded events and once with every
/// event forced through the closure escape hatch.
fn run_workload(force_closures: bool) -> World {
    let mut w = world_with_store();
    let mut head = lambda("head");
    head.ops.push(Op::InvokeNext {
        function: "tail".into(),
        trigger: TriggerService::Direct,
    });
    w.deploy(head);
    w.deploy(lambda("tail"));
    let mut sim: PlatformSim = Sim::new();
    sim.max_events = 10_000_000;
    sim.force_closures = force_closures;
    for i in 0..12u64 {
        sim.schedule(SimDuration::from_secs(2 + i * 7), |sim, w| {
            invoke(sim, w, "head");
        });
    }
    sim.schedule(SimDuration::from_secs(40), |sim, w| {
        start_freshen(sim, w, "tail", None);
    });
    sim.run(&mut w);
    w
}

#[test]
fn enum_events_are_equivalent_to_closure_events() {
    let fast = run_workload(false);
    let legacy = run_workload(true);
    // The workload actually exercises the interesting event shapes.
    assert!(fast.metrics.count() >= 24, "head + chained tail both ran");
    assert!(fast.metrics.cold_starts >= 2);
    assert!(fast.metrics.freshens_started >= 1, "freshen events fired");
    // Counters match exactly...
    assert_eq!(fast.metrics.count(), legacy.metrics.count());
    assert_eq!(fast.metrics.cold_starts, legacy.metrics.cold_starts);
    assert_eq!(fast.metrics.warm_starts, legacy.metrics.warm_starts);
    assert_eq!(fast.metrics.freshens_started, legacy.metrics.freshens_started);
    assert_eq!(fast.metrics.freshens_completed, legacy.metrics.freshens_completed);
    assert_eq!(fast.metrics.freshens_wasted, legacy.metrics.freshens_wasted);
    assert_eq!(fast.metrics.evictions, legacy.metrics.evictions);
    // ...and so does the full per-invocation record stream, timestamps
    // included: the two encodings schedule at identical (time, seq) keys.
    let key = |w: &World| {
        w.metrics
            .records()
            .iter()
            .map(|r| {
                (
                    r.function.clone(),
                    r.enqueued_at,
                    r.started_at,
                    r.finished_at,
                    r.start_kind,
                    r.freshen_hits,
                    r.freshen_misses,
                )
            })
            .collect::<Vec<_>>()
    };
    assert_eq!(key(&fast), key(&legacy), "record streams diverged");
    // Slab bookkeeping is representation-independent too.
    assert_eq!(fast.invocations.total(), legacy.invocations.total());
    assert_eq!(fast.ledger.account("app").invocations, legacy.ledger.account("app").invocations);
}
