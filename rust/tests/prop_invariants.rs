//! Property-based invariants over the coordinator (routing, batching,
//! fr_state) using the in-repo harness (`testkit::prop`, the offline
//! proptest substitute).

use freshen_rs::freshen::state::{Completer, FrEntry, FrResult, FrStatus};
use freshen_rs::freshen::wrappers::{fr_fetch_decision, WrapperDecision};
use freshen_rs::netsim::cc::{CcState, CongestionControl, INIT_CWND_SEGMENTS, MSS};
use freshen_rs::netsim::link::Site;
use freshen_rs::netsim::tcp::Connection;
use freshen_rs::platform::endpoint::Endpoint;
use freshen_rs::platform::exec::invoke;
use freshen_rs::platform::function::FunctionSpec;
use freshen_rs::platform::world::{PlatformSim, World};
use freshen_rs::simcore::Sim;
use freshen_rs::testkit::prop::forall;
use freshen_rs::util::config::{
    Config, HostClass, KeepAliveKind, MemoryAccounting, PlacementKind, QueueKind,
};
use freshen_rs::util::rng::Rng;
use freshen_rs::util::stats::{Cdf, Summary};
use freshen_rs::util::time::{SimDuration, SimTime};

#[test]
fn prop_cdf_is_monotone_and_bounded() {
    forall("cdf monotone", 100, |g| {
        let n = g.usize(1, 200);
        let xs: Vec<f64> = (0..n).map(|_| g.f64(-1e3, 1e3)).collect();
        let cdf = Cdf::of(&xs);
        let mut prev = 0.0;
        for i in -10..=10 {
            let f = cdf.at(i as f64 * 100.0);
            assert!((0.0..=1.0).contains(&f));
            assert!(f >= prev);
            prev = f;
        }
        assert_eq!(cdf.at(1e9), 1.0);
    });
}

#[test]
fn prop_summary_percentiles_ordered() {
    forall("summary ordered", 100, |g| {
        let n = g.usize(1, 300);
        let xs: Vec<f64> = (0..n).map(|_| g.f64(0.0, 1e4)).collect();
        let s = Summary::of(&xs).unwrap();
        assert!(s.min <= s.p25 && s.p25 <= s.p50);
        assert!(s.p50 <= s.p90 && s.p90 <= s.p95 && s.p95 <= s.p99);
        assert!(s.p99 <= s.max);
        assert!(s.min <= s.mean && s.mean <= s.max);
    });
}

#[test]
fn prop_cwnd_never_below_floor_nor_negative() {
    // Any sequence of rounds, losses, idles, and warms keeps the window in
    // a sane band.
    forall("cwnd band", 150, |g| {
        let algo = *g.choice(&[CongestionControl::Reno, CongestionControl::Cubic]);
        let mut cc = CcState::new(algo);
        for _ in 0..g.usize(1, 60) {
            match g.usize(0, 3) {
                0 => cc.on_round(g.f64(0.0, cc.cwnd), g.f64(1e-4, 0.2)),
                1 => cc.on_loss(),
                2 => cc.apply_idle_decay(g.f64(0.0, 1e4), g.f64(0.05, 1.0)),
                _ => cc.set_cwnd(g.f64(0.0, 1e8)),
            }
            assert!(cc.cwnd >= 2.0 * MSS - 1.0, "cwnd {} too small", cc.cwnd);
            assert!(cc.cwnd.is_finite());
            assert!(cc.ssthresh >= 2.0 * MSS - 1.0 || cc.ssthresh.is_infinite());
        }
    });
}

#[test]
fn prop_transfer_time_monotone_in_size() {
    // Bigger transfers on identical fresh connections never finish sooner
    // (jitter disabled).
    forall("transfer monotone", 60, |g| {
        let site = *g.choice(&[Site::Local, Site::Edge, Site::Remote]);
        let mut link = site.link();
        link.jitter_sigma = 0.0;
        let a = g.f64(1e2, 1e7);
        let b = a * g.f64(1.0, 10.0);
        let seed = g.u64(0, u64::MAX / 2);
        let mut t = |bytes: f64| {
            let mut conn = Connection::new(link.clone(), CongestionControl::Cubic);
            let mut rng = Rng::new(seed);
            let d = conn.connect(SimTime::ZERO, &mut rng);
            conn.send_with_ack(SimTime::ZERO + d, &mut rng, bytes, 0.0)
                .as_secs_f64()
        };
        assert!(t(b) >= t(a) * 0.999, "size {a} vs {b}");
    });
}

#[test]
fn prop_fr_entry_state_machine_is_sound() {
    // Random interleavings of try_start/finish/recycle/decide never panic
    // and never let two workers own the same resource.
    forall("fr_state machine", 200, |g| {
        let ttl = SimDuration::from_secs(g.u64(1, 30));
        let mut entry = FrEntry::new(ttl);
        let mut owner: Option<u8> = None; // who holds Running
        let mut now = SimTime::ZERO;
        for _ in 0..g.usize(1, 40) {
            now = now + SimDuration::from_millis(g.u64(0, 20_000));
            match g.usize(0, 2) {
                0 => {
                    // A worker tries to claim.
                    let who = g.u64(0, 1) as u8;
                    if entry.try_start(now) {
                        assert!(owner.is_none(), "double ownership");
                        owner = Some(who);
                    }
                }
                1 => {
                    // The owner finishes.
                    if owner.take().is_some() {
                        let result = if g.bool(0.8) {
                            FrResult::Data {
                                object_id: "x".into(),
                                version: g.u64(1, 5),
                                bytes: 10.0,
                            }
                        } else {
                            FrResult::Failed
                        };
                        entry.finish(result, now, Completer::Freshen);
                    }
                }
                _ => {
                    if owner.is_none() {
                        entry.recycle(now);
                    }
                }
            }
            // Invariants.
            match entry.status {
                FrStatus::Running => assert!(owner.is_some()),
                _ => assert!(owner.is_none()),
            }
            if entry.is_fresh(now) {
                assert!(matches!(
                    entry.result,
                    Some(FrResult::Data { .. }) | Some(FrResult::Warmed)
                ));
            }
        }
    });
}

#[test]
fn prop_fetch_decision_claims_exactly_one_worker() {
    // N workers race on one NotRun entry: exactly one gets DoItYourself,
    // the rest Wait.
    forall("single claimer", 100, |g| {
        let mut entry = FrEntry::new(SimDuration::from_secs(10));
        let workers = g.usize(2, 8);
        let mut doers = 0;
        let mut waiters = 0;
        for _ in 0..workers {
            match fr_fetch_decision(&mut entry, SimTime::ZERO, None) {
                WrapperDecision::DoItYourself => doers += 1,
                WrapperDecision::Wait => waiters += 1,
                WrapperDecision::UseResult(_) => panic!("nothing finished yet"),
            }
        }
        assert_eq!(doers, 1);
        assert_eq!(waiters, workers - 1);
    });
}

#[test]
fn prop_platform_conserves_invocations() {
    // Whatever the arrival pattern and pool size: every submitted
    // invocation completes exactly once, and freshen never changes that.
    forall("invocation conservation", 25, |g| {
        let mut cfg = Config::default();
        cfg.seed = g.u64(0, u64::MAX / 2);
        cfg.invokers = g.usize(1, 3);
        cfg.containers_per_invoker = g.usize(1, 4);
        cfg.freshen.enabled = g.bool(0.5);
        cfg.freshen.min_confidence = 0.0;
        // Short eviction so full pools recycle within the test horizon.
        cfg.idle_eviction = SimDuration::from_secs(g.u64(5, 60));
        let mut w = World::new(cfg);
        let mut ep = Endpoint::new("store", Site::Edge);
        ep.store.put("ID1", g.f64(1e3, 1e6), SimTime::ZERO);
        w.add_endpoint(ep);
        let nfns = g.usize(1, 4);
        for f in 0..nfns {
            w.deploy(FunctionSpec::paper_lambda(
                &format!("f{f}"),
                "app",
                "store",
                SimDuration::from_millis(g.u64(1, 50)),
            ));
        }
        let mut sim: PlatformSim = Sim::new();
        sim.max_events = 20_000_000;
        let n = g.usize(1, 30);
        for _ in 0..n {
            let f = format!("f{}", g.usize(0, nfns - 1));
            let at = SimTime(g.u64(0, 120_000_000));
            sim.schedule_at(at, move |sim, w| {
                invoke(sim, w, &f);
            });
        }
        sim.run(&mut w);
        // The debug accounting cross-check (used_mb == Σ charged_mb per
        // host, resident_mb == the grand total) must hold at quiescence —
        // the world also re-checks it at every charge/release internally.
        w.debug_check_memory_accounting();
        assert_eq!(w.metrics.count(), n, "all invocations completed");
        // Every record is coherent.
        for r in w.metrics.records() {
            assert!(r.finished_at >= r.started_at);
            assert!(r.started_at >= r.enqueued_at);
        }
        // Container accounting: busy containers all drained.
        assert!(w
            .containers
            .iter()
            .all(|c| c.state != freshen_rs::platform::container::ContainerState::Busy));
    });
}

#[test]
fn prop_initial_cwnd_is_rfc6928() {
    assert_eq!(Connection::initial_cwnd(), INIT_CWND_SEGMENTS * MSS);
}

/// The cross-policy conservation property (the dispatch subsystem's
/// acceptance bar): for EVERY queue discipline × keep-alive policy ×
/// memory-accounting combination, a randomized contention workload ends
/// with
///
///   scheduled == completed + explicitly-dropped,
///
/// no stranded dispatch-queue entries, no double dispatch, no busy
/// containers, and coherent per-invocation timelines. One function's
/// charge is deliberately infeasible (larger than any host) under
/// per-function accounting, so the explicit-drop bucket is exercised
/// rather than vacuous; everything else fits a host by construction.
#[test]
fn prop_conservation_across_queue_keepalive_and_accounting() {
    forall("queue x keep-alive x accounting conservation", 8, |g| {
        let seed = g.u64(0, u64::MAX / 2);
        let invokers = g.usize(1, 2);
        let slots = g.usize(1, 3);
        let nfns = g.usize(2, 5);
        let n = g.usize(5, 40);
        // Pre-draw the workload so every grid cell replays the SAME
        // arrivals (the property is per-cell; drawing inside the cell
        // loop would give each cell a different workload, which is fine
        // too but makes failures harder to compare).
        let arrivals: Vec<(usize, u64)> = (0..n)
            .map(|_| (g.usize(0, nfns - 1), g.u64(0, 90_000_000)))
            .collect();
        let mut memories: Vec<u32> = (0..nfns).map(|_| g.u64(64, 256) as u32).collect();
        // f0's charge exceeds ANY host under per-function accounting
        // (capacity tops out at 3 slots × 256 MB); under uniform slots it
        // charges 256 like everyone else and completes.
        memories[0] = 10_000;
        let durations: Vec<u64> = (0..nfns).map(|_| g.u64(1, 2_000)).collect();
        let freshen_on = g.bool(0.5);
        let guard_on = g.bool(0.5);
        for queue in QueueKind::all() {
            for keep_alive in KeepAliveKind::all() {
                for accounting in [MemoryAccounting::UniformSlot, MemoryAccounting::FunctionMb] {
                    let mut cfg = Config::default();
                    cfg.seed = seed;
                    cfg.invokers = invokers;
                    cfg.containers_per_invoker = slots;
                    cfg.queue = queue;
                    cfg.keep_alive = keep_alive;
                    cfg.memory_accounting = accounting;
                    cfg.freshen.enabled = freshen_on;
                    cfg.freshen.min_confidence = 0.0;
                    cfg.freshen_incarnation_guard = guard_on;
                    cfg.idle_eviction = SimDuration::from_secs(30);
                    let mut w = World::new(cfg);
                    let mut ep = Endpoint::new("store", Site::Edge);
                    ep.store.put("ID1", 1e5, SimTime::ZERO);
                    w.add_endpoint(ep);
                    for f in 0..nfns {
                        let mut spec = FunctionSpec::paper_lambda(
                            &format!("f{f}"),
                            "app",
                            "store",
                            SimDuration::from_millis(durations[f]),
                        );
                        // f0 is deliberately infeasible under FunctionMb
                        // (see `memories` above); the rest fit one slot.
                        spec.memory_mb = memories[f];
                        w.deploy(spec);
                    }
                    let mut sim: PlatformSim = Sim::new();
                    sim.max_events = 20_000_000;
                    for &(f, at) in &arrivals {
                        let name = format!("f{f}");
                        sim.schedule_at(SimTime(at), move |sim, w| {
                            invoke(sim, w, &name);
                        });
                    }
                    sim.run(&mut w);
                    let tag = format!(
                        "queue={} keep_alive={:?} accounting={:?}",
                        queue.as_str(),
                        keep_alive,
                        accounting
                    );
                    w.debug_check_memory_accounting();
                    // Conservation: scheduled == completed + explicitly-
                    // dropped; nothing stranded, nothing double-dispatched.
                    assert_eq!(
                        w.metrics.count() as u64 + w.metrics.dropped_infeasible,
                        n as u64,
                        "lost/duplicated invocations [{tag}]"
                    );
                    if accounting == MemoryAccounting::UniformSlot {
                        assert_eq!(
                            w.metrics.dropped_infeasible, 0,
                            "uniform slots are always feasible [{tag}]"
                        );
                    }
                    assert_eq!(
                        w.invocations.iter().filter(|c| c.done).count(),
                        n,
                        "every context must terminate [{tag}]"
                    );
                    assert!(
                        w.dispatch.is_empty(),
                        "stranded queue entries [{tag}]"
                    );
                    assert!(
                        w.containers.iter().all(|c| c.state
                            != freshen_rs::platform::container::ContainerState::Busy),
                        "busy container at quiescence [{tag}]"
                    );
                    for r in w.metrics.records() {
                        assert!(r.finished_at >= r.started_at, "[{tag}]");
                        assert!(r.started_at >= r.enqueued_at, "[{tag}]");
                    }
                    // The start-kind split accounts for every completion
                    // (no snapshot axis here, so restored starts are
                    // provably zero and the legacy two-way split holds).
                    assert_eq!(
                        w.metrics.cold_starts + w.metrics.warm_starts,
                        w.metrics.count() as u64,
                        "start kinds must partition completions [{tag}]"
                    );
                    assert_eq!(w.metrics.restored_starts, 0, "[{tag}]");
                    // Release/charge pairing never went negative.
                    assert_eq!(
                        w.metrics.accounting_clamps, 0,
                        "mispaired memory release [{tag}]"
                    );
                }
            }
        }
    });
}

/// Conservation over the placement axis: every placement strategy ×
/// cluster shape (homogeneous, heterogeneous host classes) ends a
/// randomized contention workload with scheduled == completed +
/// explicitly-dropped, nothing stranded and nothing double-dispatched —
/// same bar as the queue/keep-alive grid above. One function carries
/// affinity labels, so `Constrained` genuinely restricts (and, on the
/// label-less homogeneous cluster, genuinely drops).
#[test]
fn prop_conservation_across_placement_and_host_classes() {
    forall("placement x host-class conservation", 6, |g| {
        let seed = g.u64(0, u64::MAX / 2);
        let nfns = g.usize(2, 5);
        let n = g.usize(5, 40);
        let arrivals: Vec<(usize, u64)> = (0..n)
            .map(|_| (g.usize(0, nfns - 1), g.u64(0, 90_000_000)))
            .collect();
        let mut memories: Vec<u32> = (0..nfns).map(|_| g.u64(64, 256) as u32).collect();
        // f0's charge exceeds ANY host (cloud tops out at 768 MB on the
        // heterogeneous cluster, 3 × 256 on the homogeneous one), so the
        // explicit-drop bucket is exercised under per-function accounting.
        memories[0] = 10_000;
        let durations: Vec<u64> = (0..nfns).map(|_| g.u64(1, 2_000)).collect();
        let queue = *g.choice(&QueueKind::all());
        let keep_alive = *g.choice(&KeepAliveKind::all());
        let freshen_on = g.bool(0.5);
        for placement in PlacementKind::all() {
            for hetero in [false, true] {
                let mut cfg = Config::default();
                cfg.seed = seed;
                cfg.invokers = 2;
                cfg.containers_per_invoker = 3;
                cfg.queue = queue;
                cfg.keep_alive = keep_alive;
                cfg.placement = placement;
                cfg.memory_accounting = MemoryAccounting::FunctionMb;
                cfg.freshen.enabled = freshen_on;
                cfg.freshen.min_confidence = 0.0;
                cfg.idle_eviction = SimDuration::from_secs(30);
                if hetero {
                    cfg.host_classes = HostClass::parse_list(
                        "cloud:1:768:1000:local,edge:2:512:1500:edge",
                    )
                    .expect("valid host-class spec");
                }
                let mut w = World::new(cfg);
                let mut ep = Endpoint::new("store", Site::Edge);
                ep.store.put("ID1", 1e5, SimTime::ZERO);
                w.add_endpoint(ep);
                for f in 0..nfns {
                    let mut spec = FunctionSpec::paper_lambda(
                        &format!("f{f}"),
                        "app",
                        "store",
                        SimDuration::from_millis(durations[f]),
                    );
                    spec.memory_mb = memories[f];
                    // f1 is label-constrained to the cloud class: binding
                    // on the heterogeneous cluster under `Constrained`,
                    // a guaranteed drop on the label-less homogeneous one
                    // (both sides of the admit predicate get exercised).
                    if f == 1 {
                        spec.affinity = vec!["cloud".to_string()];
                    }
                    w.deploy(spec);
                }
                let mut sim: PlatformSim = Sim::new();
                sim.max_events = 20_000_000;
                for &(f, at) in &arrivals {
                    let name = format!("f{f}");
                    sim.schedule_at(SimTime(at), move |sim, w| {
                        invoke(sim, w, &name);
                    });
                }
                sim.run(&mut w);
                let tag = format!(
                    "placement={} hetero={hetero} queue={} keep_alive={:?}",
                    placement.as_str(),
                    queue.as_str(),
                    keep_alive
                );
                w.debug_check_memory_accounting();
                assert_eq!(
                    w.metrics.count() as u64 + w.metrics.dropped_infeasible,
                    n as u64,
                    "lost/duplicated invocations [{tag}]"
                );
                assert_eq!(
                    w.invocations.iter().filter(|c| c.done).count(),
                    n,
                    "every context must terminate [{tag}]"
                );
                assert!(w.dispatch.is_empty(), "stranded queue entries [{tag}]");
                assert!(
                    w.containers.iter().all(|c| c.state
                        != freshen_rs::platform::container::ContainerState::Busy),
                    "busy container at quiescence [{tag}]"
                );
                for r in w.metrics.records() {
                    assert!(r.finished_at >= r.started_at, "[{tag}]");
                    assert!(r.started_at >= r.enqueued_at, "[{tag}]");
                }
                assert_eq!(
                    w.metrics.cold_starts + w.metrics.warm_starts,
                    w.metrics.count() as u64,
                    "start kinds must partition completions [{tag}]"
                );
                assert_eq!(
                    w.metrics.accounting_clamps, 0,
                    "mispaired memory release [{tag}]"
                );
            }
        }
    });
}

/// Conservation over the cold-start mitigation axis: with the snapshot
/// path enabled (alone, and combined with freshen-on-restore), every
/// queue × keep-alive cell under per-function accounting still ends with
///
///   scheduled == completed + explicitly-dropped,
///
/// the THREE start kinds (cold/warm/restored) partitioning completions,
/// restores never outnumbering the snapshots that feed them, memory
/// accounting exact (a parked container holds its discounted charge;
/// `debug_check_memory_accounting` cross-sums per-container `charged_mb`
/// against per-host `used_mb`), and zero accounting clamps. A container
/// state is a single enum, so "warm AND snapshotted at once" is
/// structurally impossible — the checks here pin the observable side:
/// parked containers carry a nonzero discounted charge and nothing is
/// busy at quiescence.
#[test]
fn prop_conservation_across_mitigation_cells() {
    forall("mitigation x queue x keep-alive conservation", 6, |g| {
        let seed = g.u64(0, u64::MAX / 2);
        let nfns = g.usize(2, 4);
        let n = g.usize(5, 40);
        let arrivals: Vec<(usize, u64)> = (0..n)
            .map(|_| (g.usize(0, nfns - 1), g.u64(0, 120_000_000)))
            .collect();
        let mut memories: Vec<u32> = (0..nfns).map(|_| g.u64(64, 256) as u32).collect();
        // f0's charge exceeds ANY host, so the explicit-drop bucket stays
        // exercised under the new axis too.
        memories[0] = 10_000;
        let durations: Vec<u64> = (0..nfns).map(|_| g.u64(1, 2_000)).collect();
        for mitigation in ["keepalive", "snapshot", "hybrid"] {
            for queue in QueueKind::all() {
                for keep_alive in KeepAliveKind::all() {
                    let mut cfg = Config::default();
                    cfg.seed = seed;
                    cfg.invokers = 2;
                    cfg.containers_per_invoker = 2;
                    cfg.queue = queue;
                    cfg.keep_alive = keep_alive;
                    cfg.memory_accounting = MemoryAccounting::FunctionMb;
                    // Short TTL so idle expiry (the demotion trigger) fires
                    // inside the 120 s arrival window, not only at drain.
                    cfg.idle_eviction = SimDuration::from_secs(20);
                    match mitigation {
                        "snapshot" => cfg.snapshot.enabled = true,
                        "hybrid" => {
                            cfg.snapshot.enabled = true;
                            cfg.snapshot.freshen_on_restore = true;
                            cfg.freshen.enabled = true;
                            cfg.freshen.min_confidence = 0.0;
                        }
                        _ => {}
                    }
                    let mut w = World::new(cfg);
                    let mut ep = Endpoint::new("store", Site::Edge);
                    ep.store.put("ID1", 1e5, SimTime::ZERO);
                    w.add_endpoint(ep);
                    for f in 0..nfns {
                        let mut spec = FunctionSpec::paper_lambda(
                            &format!("f{f}"),
                            "app",
                            "store",
                            SimDuration::from_millis(durations[f]),
                        );
                        spec.memory_mb = memories[f];
                        w.deploy(spec);
                    }
                    let mut sim: PlatformSim = Sim::new();
                    sim.max_events = 20_000_000;
                    for &(f, at) in &arrivals {
                        let name = format!("f{f}");
                        sim.schedule_at(SimTime(at), move |sim, w| {
                            invoke(sim, w, &name);
                        });
                    }
                    sim.run(&mut w);
                    let tag = format!(
                        "mitigation={mitigation} queue={} keep_alive={:?}",
                        queue.as_str(),
                        keep_alive
                    );
                    w.debug_check_memory_accounting();
                    let m = &w.metrics;
                    assert_eq!(
                        m.count() as u64 + m.dropped_infeasible,
                        n as u64,
                        "lost/duplicated invocations [{tag}]"
                    );
                    assert_eq!(
                        m.cold_starts + m.warm_starts + m.restored_starts,
                        m.count() as u64,
                        "cold/warm/restored must partition completions [{tag}]"
                    );
                    assert!(
                        m.restored_starts <= m.snapshots_created,
                        "every restore consumes a prior snapshot [{tag}]"
                    );
                    assert_eq!(
                        m.accounting_clamps, 0,
                        "mispaired memory release [{tag}]"
                    );
                    if mitigation == "keepalive" {
                        assert_eq!(m.snapshots_created, 0, "axis off never parks [{tag}]");
                        assert_eq!(m.restored_starts, 0, "[{tag}]");
                    } else if keep_alive == KeepAliveKind::FixedTtl && m.count() > 0 {
                        // FixedTtl demotes every idle-expired container; at
                        // least the last-used one expires during the drain.
                        assert!(
                            m.snapshots_created > 0,
                            "idle expiry must demote, not evict [{tag}]"
                        );
                    }
                    for c in &w.containers {
                        use freshen_rs::platform::container::ContainerState;
                        assert!(
                            c.state != ContainerState::Busy,
                            "busy container at quiescence [{tag}]"
                        );
                        if c.state == ContainerState::Snapshotted {
                            assert!(
                                c.charged_mb > 0,
                                "parked container must hold its discounted charge [{tag}]"
                            );
                        }
                    }
                    assert!(w.dispatch.is_empty(), "stranded queue entries [{tag}]");
                    for r in w.metrics.records() {
                        assert!(r.finished_at >= r.started_at, "[{tag}]");
                        assert!(r.started_at >= r.enqueued_at, "[{tag}]");
                    }
                }
            }
        }
    });
}
