//! AOT contract tests: the rust runtime (default backend: native) must
//! reproduce the numerics the python side recorded in
//! `artifacts/manifest.json`.
//!
//! Requires `make artifacts` (skips with a message when absent, so plain
//! `cargo test` works in a fresh checkout). The always-on twin of these
//! tests — against a rust-generated artifact set — lives in
//! `native_backend.rs`.

use std::path::{Path, PathBuf};

use freshen_rs::runtime::model::{ClassifierRuntime, PredictorRuntime};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    // These tests run the default (native) backend; artifact sets written
    // before the weights sidecar existed can only serve PJRT, so skip
    // rather than fail on them.
    match freshen_rs::runtime::manifest::Manifest::load(&dir) {
        Ok(m) if m.weights.is_some() => Some(dir),
        _ => {
            eprintln!("skipping: artifacts lack the weights sidecar; re-run `make artifacts`");
            None
        }
    }
}

#[test]
fn classifier_artifact_matches_python_numerics() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = ClassifierRuntime::load(&dir).expect("load classifier");
    let max_err = rt.self_check().expect("self-check");
    assert!(max_err < 1e-3, "max err {max_err}");
}

#[test]
fn classifier_handles_every_compiled_batch() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = ClassifierRuntime::load(&dir).expect("load");
    let dim = rt.manifest.input_dim;
    let classes = rt.manifest.classes;
    for n in [1usize, 2, 3, 4, 7, 8, 16] {
        if n > rt.max_batch() {
            continue;
        }
        let rows: Vec<Vec<f32>> = (0..n)
            .map(|i| (0..dim).map(|j| ((i * 31 + j) % 17) as f32 / 17.0).collect())
            .collect();
        let out = rt.infer(&rows).expect("infer");
        assert_eq!(out.len(), n);
        assert!(out.iter().all(|r| r.len() == classes));
        // Identical rows give identical logits regardless of batch size.
        if n >= 2 {
            let single = rt.infer(&rows[..1]).expect("single");
            for (a, b) in single[0].iter().zip(out[0].iter()) {
                assert!((a - b).abs() < 1e-4, "batch-size-dependent result");
            }
        }
    }
    assert!(rt.rows_served > 0);
    assert!(rt.executions > 0);
}

#[test]
fn classifier_rejects_bad_inputs_and_chunks_oversized_batches() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = ClassifierRuntime::load(&dir).expect("load");
    // Wrong feature width.
    assert!(rt.infer(&[vec![0.0; 3]]).is_err());
    // Oversized batches are chunked into max_batch slices, not rejected.
    let dim = rt.manifest.input_dim;
    let classes = rt.manifest.classes;
    let n = rt.max_batch() + 3;
    let many: Vec<Vec<f32>> = (0..n)
        .map(|i| (0..dim).map(|j| ((i * 13 + j) % 19) as f32 / 19.0).collect())
        .collect();
    let out = rt.infer(&many).expect("chunked inference");
    assert_eq!(out.len(), n);
    assert!(out.iter().all(|r| r.len() == classes));
    assert!(rt.executions >= 2, "oversized batch needs >1 execution");
    // Chunked rows match their individually-inferred logits.
    let last = rt.infer(&many[n - 1..]).expect("single");
    for (a, b) in out[n - 1].iter().zip(last[0].iter()) {
        assert!((a - b).abs() < 1e-4, "chunking changed results: {a} vs {b}");
    }
    // Empty is fine.
    assert!(rt.infer(&[]).unwrap().is_empty());
}

#[test]
fn predictor_artifact_matches_native_scorer() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = PredictorRuntime::load(&dir).expect("load predictor");
    let max_err = rt.self_check().expect("self-check");
    assert!(max_err < 1e-4, "max err {max_err}");
}

#[test]
fn predictor_scores_are_probabilities() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = PredictorRuntime::load(&dir).expect("load");
    let rows: Vec<[f32; 4]> = vec![
        [0.0, 0.0, 0.0, 0.0],
        [1.0, 1.0, 1.0, 0.0],
        [0.9, 0.0, 0.5, 0.2],
    ];
    let scores = rt.score(&rows).expect("score");
    assert_eq!(scores.len(), 3);
    assert!(scores.iter().all(|&s| (0.0..=1.0).contains(&s)));
    assert!(scores[1] > scores[0], "stronger signal scores higher");
}
