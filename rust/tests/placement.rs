//! Placement-subsystem regression tests.
//!
//! Three contracts from the multi-node placement PR:
//!
//! - **Legacy identity**: the default grid (no placement axis, homogeneous
//!   hosts) is byte-identical to an explicitly legacy-configured run, the
//!   digest labels keep the historical three-segment form, and every
//!   metrics digest still starts with the legacy field set.
//! - **Determinism per strategy**: every placement strategy keeps the
//!   macrotrace contracts — byte-identical digests (metrics AND spans)
//!   across `--shards` × `--parallel` in per-app mode, and across
//!   `--parallel` at fixed `--shards` in shared mode, heterogeneous
//!   host classes included.
//! - **Warm affinity wins locality**: under a contended multi-host world,
//!   `WarmAffinity` lands cold starts next to live containers of the
//!   function; `RandomUniform` does not.

use freshen_rs::experiments::azure_macro::{run_multi, AzureMacroCfg, Variant};
use freshen_rs::experiments::SweepRunner;
use freshen_rs::platform::World;
use freshen_rs::util::config::{Config, HostClass, PlacementKind};
use freshen_rs::util::time::SimTime;
use freshen_rs::workload::macrotrace::replay::PoolMode;
use freshen_rs::workload::macrotrace::shard::TraceSource;
use freshen_rs::workload::macrotrace::synth::SynthTraceCfg;

fn trace() -> SynthTraceCfg {
    SynthTraceCfg {
        apps: 18,
        minutes: 10,
        seed: 0x91AC_E817,
        ..SynthTraceCfg::default()
    }
}

fn cfg(shards: usize) -> AzureMacroCfg {
    let mut cfg = AzureMacroCfg::new(TraceSource::Synth(trace()));
    cfg.shards = shards;
    cfg.warmup_minutes = 3;
    cfg.variants = vec![Variant::Baseline, Variant::Both];
    cfg
}

#[test]
fn default_grid_is_byte_identical_to_explicit_legacy_placement() {
    // Golden guard for the legacy axis: a run that never mentions
    // placement must produce EXACTLY the bytes of one that spells out the
    // legacy strategy and the homogeneous cluster — the placement
    // subsystem may not perturb the default path.
    let seeds = [7u64];
    let implicit = run_multi(&cfg(2), &seeds, &SweepRunner::new(2)).unwrap();
    let mut explicit_cfg = cfg(2);
    explicit_cfg.placements = vec![PlacementKind::LeastLoadedMb];
    explicit_cfg.host_classes = None;
    let explicit = run_multi(&explicit_cfg, &seeds, &SweepRunner::new(1)).unwrap();
    assert_eq!(implicit.digest(), explicit.digest());
    // Labels keep the historical three-segment `variant/policy/queue`
    // form — no fourth segment leaks into legacy digests.
    assert!(implicit.digest().contains("baseline/fixed/legacy:"));
    for line in implicit.digest().lines() {
        let label = line.split(':').next().unwrap();
        assert_eq!(label.split('/').count(), 3, "label {label} gained a segment");
    }
    // And the metrics digest prefix is still the legacy field set.
    for row in &implicit.rows {
        assert!(
            row.metrics.digest().starts_with(&row.metrics.digest_legacy()),
            "metrics digest no longer extends the legacy prefix"
        );
    }
}

#[test]
fn every_strategy_is_shard_and_parallel_invariant_in_per_app_mode() {
    // The per-app contract (byte-identical for ANY shards × parallel)
    // must hold for every strategy: the placement RNG is seeded from the
    // world seed, which in per-app mode derives from the app — never the
    // shard map. Spans are recorded too, so the span digest pins event
    // order, not just the merged counters.
    for kind in PlacementKind::all() {
        let mk = |shards: usize| {
            let mut c = cfg(shards);
            c.placements = vec![kind];
            c.trace_spans = true;
            c
        };
        let reference = run_multi(&mk(1), &[7], &SweepRunner::new(1)).unwrap();
        for (shards, parallel) in [(2usize, 1usize), (4, 4)] {
            let r = run_multi(&mk(shards), &[7], &SweepRunner::new(parallel)).unwrap();
            assert_eq!(
                reference.digest(),
                r.digest(),
                "{kind:?}: metrics diverged at shards={shards} parallel={parallel}"
            );
            assert_eq!(
                reference.span_digest(),
                r.span_digest(),
                "{kind:?}: spans diverged at shards={shards} parallel={parallel}"
            );
        }
    }
}

#[test]
fn shared_pool_strategies_are_parallel_invariant_on_heterogeneous_hosts() {
    // Shared-pool contract at fixed shards, on a genuinely heterogeneous
    // cluster (cloud + slow edge hosts): every strategy merges to the
    // same bytes no matter how many workers ran the shards.
    for kind in PlacementKind::all() {
        let mut c = cfg(2);
        c.pool = PoolMode::Shared;
        c.variants = vec![Variant::Both];
        c.placements = vec![kind];
        c.host_classes =
            HostClass::parse_list("cloud:2:4096:1000:local,edge:2:1024:1600:edge");
        assert!(c.host_classes.is_some(), "host-class spec must parse");
        let a = run_multi(&c, &[7], &SweepRunner::new(1)).unwrap();
        let b = run_multi(&c, &[7], &SweepRunner::new(4)).unwrap();
        assert_eq!(
            a.digest(),
            b.digest(),
            "{kind:?}: shared pool diverged across --parallel at fixed --shards"
        );
        for row in &a.rows {
            assert!(row.metrics.invocations > 0, "{kind:?}: empty replay");
        }
    }
}

#[test]
fn warm_affinity_beats_random_on_warm_host_locality_under_contention() {
    // Acceptance probe: drive the world's placement path directly (the
    // exec's cold-start sequence: acquire a slot, then cold-start the
    // container) and count how many cold starts land on a host that
    // already held a live container of the function.
    let run = |kind: PlacementKind| -> usize {
        let mut config = Config::default();
        config.invokers = 4;
        config.invoker_memory_mb = Some(1024);
        config.placement = kind;
        let mut w = World::new(config);
        let hot_id = w.registry.symbols.intern("hot");
        let now = SimTime::ZERO;
        let mut hits = 0usize;
        for _ in 0..16 {
            let hot: Vec<bool> = w
                .invokers
                .iter()
                .map(|inv| {
                    inv.containers
                        .iter()
                        .any(|&cid| w.containers[cid].function == Some(hot_id))
                })
                .collect();
            let cid = w.acquire_slot_for(now, 32, hot_id).expect("cluster has room");
            if hot[w.containers[cid].invoker] {
                hits += 1;
            }
            w.containers[cid].begin_cold_start(hot_id, now);
        }
        hits
    };
    // The very first acquire can never hit (no live container anywhere),
    // and the warm host keeps room for all 16 × 32 MB, so affinity hits
    // every later acquire: 15 of 16.
    let affinity = run(PlacementKind::WarmAffinity);
    assert_eq!(affinity, 15, "affinity lands every later cold start on the warm host");
    // Random spreads: 15/15 later hits would need every independent
    // uniform draw over 4 roomy hosts to land inside the warm set before
    // it ever grows — probability (1/4)^15 ≈ 1e-9, i.e. effectively
    // deterministic for a pinned seed (Config::default().seed).
    let random = run(PlacementKind::RandomUniform);
    assert!(
        random < affinity,
        "random placement matched affinity's locality: {random} hits"
    );
}
