//! Trace replay: synthesize an Azure-like invocation trace (Figure 2's
//! population + periodic/bursty arrivals), write it as JSON lines, replay
//! it through the platform twice (freshen off/on), and compare.
//!
//! Run: `cargo run --release --example trace_replay`

use freshen_rs::netsim::link::Site;
use freshen_rs::platform::endpoint::Endpoint;
use freshen_rs::platform::exec::invoke;
use freshen_rs::platform::function::FunctionSpec;
use freshen_rs::platform::world::World;
use freshen_rs::simcore::Sim;
use freshen_rs::util::config::Config;
use freshen_rs::util::rng::Rng;
use freshen_rs::util::time::{SimDuration, SimTime};
use freshen_rs::workload::generator::ArrivalProcess;
use freshen_rs::workload::trace::{read_trace, write_trace, TraceRecord};

const FUNCTIONS: usize = 6;
const HORIZON_S: u64 = 600;

fn main() {
    // 1. Synthesize: half periodic (cron-like, predictable), half bursty.
    let mut rng = Rng::new(0x7ACE);
    let mut records = Vec::new();
    for f in 0..FUNCTIONS {
        let process = if f % 2 == 0 {
            ArrivalProcess::Periodic {
                period: SimDuration::from_secs(30 + 7 * f as u64),
                jitter: 0.03,
            }
        } else {
            ArrivalProcess::Bursty {
                burst_len: 3,
                intra: SimDuration::from_millis(250),
                off_mean_s: 60.0,
            }
        };
        for at in process.generate(SimDuration::from_secs(HORIZON_S), &mut rng) {
            records.push(TraceRecord {
                at,
                function: format!("fn-{f}"),
            });
        }
    }
    records.sort_by_key(|r| r.at);

    // 2. Write + read back (exercises the trace format end to end).
    let path = std::env::temp_dir().join("freshen-trace.jsonl");
    let file = std::fs::File::create(&path).expect("create trace");
    write_trace(&records, file).expect("write trace");
    let (replayed, skipped) =
        read_trace(std::io::BufReader::new(std::fs::File::open(&path).unwrap()));
    assert_eq!(skipped, 0);
    println!(
        "trace: {} invocations over {} functions, {}s horizon -> {}",
        replayed.len(),
        FUNCTIONS,
        HORIZON_S,
        path.display()
    );

    // 3. Replay twice.
    for freshen in [false, true] {
        let mut cfg = Config::default();
        cfg.seed = 1;
        cfg.freshen.enabled = freshen;
        cfg.freshen.min_confidence = 0.3;
        let mut w = World::new(cfg);
        let mut store = Endpoint::new("store", Site::Remote);
        store.store.put("ID1", 5e6, SimTime::ZERO);
        w.add_endpoint(store);
        for f in 0..FUNCTIONS {
            w.deploy(FunctionSpec::paper_lambda(
                &format!("fn-{f}"),
                "trace-app",
                "store",
                SimDuration::from_millis(15),
            ));
        }
        let mut sim: Sim<World> = Sim::new();
        sim.max_events = 100_000_000;
        for rec in &replayed {
            let f = rec.function.clone();
            sim.schedule_at(rec.at, move |sim, w| {
                invoke(sim, w, &f);
            });
        }
        sim.run(&mut w);
        let s = w.metrics.latency_summary(None).unwrap();
        println!(
            "  freshen={:<5} p50 {:>8.1} ms  p99 {:>8.1} ms  cold {}  hit rate {:>3.0}%  wasted freshens {}",
            freshen,
            s.p50,
            s.p99,
            w.metrics.cold_starts,
            100.0 * w.metrics.freshen_hit_rate(),
            w.metrics.freshens_wasted,
        );
    }
}
