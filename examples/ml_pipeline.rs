//! End-to-end driver: the paper's λ1 served for real.
//!
//! Loads the AOT-compiled JAX/Pallas classifier (build with
//! `make artifacts`), starts the real-time serving engine (router, handler
//! workers, dynamic batcher, PJRT inference thread), and serves bursts of
//! image-classification requests twice: vanilla, then with the freshen
//! hook pre-arming each burst. Reports latency/throughput for both.
//!
//! Run: `make artifacts && cargo run --release --example ml_pipeline`

use std::path::PathBuf;
use std::time::Duration;

use freshen_rs::serve::{ServeConfig, ServeEngine, ServeReport};

const BURSTS: usize = 4;
const BURST_SIZE: usize = 16;
/// Gap between bursts, real time. With time_scale=0.001 this corresponds
/// to 100 simulated seconds — far past the prefetch TTL and deep into
/// connection idle decay, the regime the paper targets.
const BURST_GAP: Duration = Duration::from_millis(100);

fn image(seed: usize) -> Vec<f32> {
    (0..3072)
        .map(|j| ((seed * 131 + j) % 23) as f32 / 23.0 - 0.5)
        .collect()
}

fn run_mode(artifacts: PathBuf, freshen: bool) -> anyhow::Result<ServeReport> {
    let engine = ServeEngine::start(
        artifacts,
        ServeConfig {
            freshen,
            workers: 4,
            max_batch: 16,
            ..ServeConfig::default()
        },
    )?;
    for burst in 0..BURSTS {
        if freshen {
            // The prediction window: the platform anticipates the burst
            // (e.g. from a chain trigger or the IAT histogram) and runs
            // freshen just ahead of it.
            engine.freshen().join().ok();
        }
        let rxs: Vec<_> = (0..BURST_SIZE)
            .map(|i| engine.submit(image(burst * BURST_SIZE + i)))
            .collect();
        for rx in rxs {
            let out = rx.recv_timeout(Duration::from_secs(60))?;
            assert_eq!(out.logits.len(), 10);
        }
        std::thread::sleep(BURST_GAP);
        engine.recycle();
    }
    Ok(engine.shutdown())
}

fn main() -> anyhow::Result<()> {
    let artifacts = PathBuf::from("artifacts");
    if !artifacts.join("manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(2);
    }

    println!(
        "serving {} bursts x {} requests of 32x32x3 image classification",
        BURSTS, BURST_SIZE
    );
    println!("(latencies include netsim-modelled store access at 1000x compression)\n");

    let baseline = run_mode(artifacts.clone(), false)?;
    let freshened = run_mode(artifacts, true)?;

    baseline.print("baseline");
    freshened.print("freshen");

    let b = baseline.latency_ms.as_ref().map(|s| s.p50).unwrap_or(0.0);
    let f = freshened.latency_ms.as_ref().map(|s| s.p50).unwrap_or(0.0);
    if f > 0.0 {
        println!("\np50 speedup from freshen: {:.2}x", b / f);
    }
    println!(
        "store GETs: baseline {} vs freshen {} (prefetch reuse saves traffic)",
        baseline.store_gets, freshened.store_gets
    );
    Ok(())
}
