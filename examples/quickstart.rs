//! Quickstart: deploy the paper's λ (Algorithm 1), invoke it, freshen it,
//! and watch the latency difference.
//!
//! Run: `cargo run --release --example quickstart`

use freshen_rs::netsim::link::Site;
use freshen_rs::platform::endpoint::Endpoint;
use freshen_rs::platform::exec::{invoke, start_freshen};
use freshen_rs::platform::function::FunctionSpec;
use freshen_rs::platform::world::World;
use freshen_rs::simcore::Sim;
use freshen_rs::util::config::Config;
use freshen_rs::util::time::{SimDuration, SimTime};

fn main() {
    // 1. A platform with one remote object store, 50 ms away.
    let mut world = World::new(Config::default());
    let mut store = Endpoint::new("store", Site::Remote);
    store.store.put("ID1", 5e6, SimTime::ZERO); // the 5 MB model λ fetches
    world.add_endpoint(store);

    // 2. Deploy λ: DataGet(CREDS, ID1) -> compute -> DataPut(CREDS, ID2).
    //    Deployment runs the provider's freshen inference (§3.3): constant
    //    credentials/ids make both resource ops freshenable.
    world.deploy(FunctionSpec::paper_lambda(
        "lambda",
        "quickstart-app",
        "store",
        SimDuration::from_millis(20),
    ));
    let hook = world.registry.hook("lambda").unwrap();
    println!("inferred freshen hook: {} actions", hook.len());
    for (idx, action) in &hook.actions {
        println!("  fr_state[{idx}] <- {action:?}");
    }

    // 3. Three invocations on the simulator substrate:
    //    a) cold start, b) warm but un-freshened (30 s later: prefetch TTL
    //    expired, connection windows decayed), c) warm AND freshened 1 s
    //    in advance.
    let mut sim: Sim<World> = Sim::new();
    invoke(&mut sim, &mut world, "lambda");
    sim.schedule(SimDuration::from_secs(30), |sim, w| {
        invoke(sim, w, "lambda");
    });
    sim.schedule(SimDuration::from_secs(59), |sim, w| {
        start_freshen(sim, w, "lambda", None);
    });
    sim.schedule(SimDuration::from_secs(60), |sim, w| {
        invoke(sim, w, "lambda");
    });
    sim.run(&mut world);

    // 4. Report.
    println!("\ninvocation latencies:");
    let labels = ["cold start", "warm, no freshen", "warm + freshen"];
    for (rec, label) in world.metrics.records().iter().zip(labels.iter()) {
        println!(
            "  {label:<18} {:>10}  (freshen hits {}/{})",
            format!("{}", rec.latency()),
            rec.freshen_hits,
            rec.freshen_hits + rec.freshen_misses,
        );
    }
    let acct = world.ledger.account("quickstart-app");
    println!(
        "\nbilling: exec {:.4} GB-s, freshen {:.4} GB-s, network {:.1} MB (saved {:.1} MB)",
        acct.exec_gb_s,
        acct.freshen_useful_gb_s + acct.freshen_wasted_gb_s,
        acct.network_bytes / 1e6,
        acct.network_bytes_saved / 1e6,
    );
}
