//! Orchestrated function chains (Figures 1 & 2): a Step-Functions-style
//! pipeline where each stage's trigger commit predicts the next stage,
//! giving freshen its window (Table 1's trigger delays).
//!
//! Run: `cargo run --release --example chain_orchestration`

use freshen_rs::experiments::e2e;
use freshen_rs::netsim::link::Site;
use freshen_rs::platform::endpoint::Endpoint;
use freshen_rs::platform::exec::invoke;
use freshen_rs::platform::function::{Arg, FunctionSpec, Op};
use freshen_rs::platform::world::World;
use freshen_rs::simcore::Sim;
use freshen_rs::triggers::TriggerService;
use freshen_rs::util::config::Config;
use freshen_rs::util::time::{SimDuration, SimTime};

fn main() {
    // Part 1: the packaged E2E experiment (baseline vs freshen).
    let e = e2e::run(2020, 60);
    e.print();

    // Part 2: trigger choice matters — the slower the trigger service,
    // the longer freshen's lead and the better the successor's latency.
    println!("\n== trigger service vs successor latency (freshen on) ==");
    for trigger in TriggerService::all() {
        let mut cfg = Config::default();
        cfg.seed = 7;
        cfg.freshen.min_confidence = 0.3;
        let mut w = World::new(cfg);
        let mut store = Endpoint::new("store", Site::Remote);
        store.store.put("model", 5e6, SimTime::ZERO);
        w.add_endpoint(store);
        w.deploy(FunctionSpec::new(
            "head",
            "chain-app",
            vec![
                Op::Compute {
                    duration: SimDuration::from_millis(10),
                },
                Op::InvokeNext {
                    function: "tail".into(),
                    trigger,
                },
            ],
        ));
        w.deploy(FunctionSpec::new(
            "tail",
            "chain-app",
            vec![
                Op::DataGet {
                    endpoint: "store".into(),
                    creds: Arg::Const("CREDS".into()),
                    object_id: Arg::Const("model".into()),
                },
                Op::Compute {
                    duration: SimDuration::from_millis(10),
                },
            ],
        ));
        w.registry
            .register_chain("c", vec!["head".into(), "tail".into()])
            .unwrap();

        let mut sim: Sim<World> = Sim::new();
        // Pre-warm tail's container, then run 10 chains 40 s apart.
        invoke(&mut sim, &mut w, "tail");
        for i in 0..10u64 {
            sim.schedule(SimDuration::from_secs(10 + i * 40), |sim, w| {
                invoke(sim, w, "head");
            });
        }
        sim.run(&mut w);
        let summary = w.metrics.latency_summary(Some("tail")).unwrap();
        println!(
            "  {:<16} lead≈{:<8} tail p50 {:>8.1} ms  freshen hit rate {:>4.0}%",
            trigger.as_str(),
            format!("{}", trigger.expected_lead()),
            summary.p50,
            100.0 * w.metrics.freshen_hit_rate()
        );
    }
}
